//! End-to-end smoke test for the inference serving subsystem:
//! submit → micro-batch → encode (work-stealing pipeline) → AM score →
//! respond, checked against offline references.
//!
//! Acceptance contract (ISSUE 5):
//! * the **f32** store's served top-1 equals offline
//!   [`LogisticModel`] scoring (sign of θ·φ + b) — margin-guarded
//!   against f32-vs-f64 accumulation for near-zero scores;
//! * the **binarized** store agrees with a naive unpacked ±1 reference
//!   **bit-for-bit** (integer scores, no tolerance);
//! * the steady-state serve loop recycles its buffers (asserted via the
//!   pipeline recycle counters here; the allocation-counter harness in
//!   `tests/alloc_regression.rs` pins the stronger zero-alloc claim).
//!
//! Multi-tenant contract (ISSUE 7), pinned by the `multi_model_` tests:
//! * two registry models with different dimensionality, seeds and store
//!   precisions served through one shared pool return answers
//!   bit-identical to *their* model's offline encode + top-1;
//! * encode batches are model-homogeneous (a mixed queue produces
//!   `model_cuts`);
//! * a tenant that exceeds its quota sheds fail-fast, with per-model
//!   counters proving it, while a quiet tenant sees zero errors and a
//!   bounded tail.
//!
//! Sharded-scan contract (ISSUE 8), pinned by
//! `many_class_sharded_serve_matches_offline_single_scan`:
//! * a 1k-class Zipf-skewed workload served through the **sharded** AM
//!   scan (`am_shards` > 1) returns answers bit-identical to the
//!   offline single-scan top-1 of the same store;
//! * the per-shard scan counters reconcile — every shard covers its
//!   slice of the class space, the slices partition all classes, and
//!   each shard is scanned exactly once per scored request.

use std::sync::Arc;
use std::thread;
use std::time::Duration;

use shdc::am::{AmScratch, AmStore, Precision};
use shdc::coordinator::{CatCfg, CoordinatorCfg, EncoderCfg, NumCfg};
use shdc::data::synthetic::SyntheticConfig;
use shdc::data::{Record, RecordStream, SyntheticStream};
use shdc::encoding::{BundleMethod, Encoding};
use shdc::model::LogisticModel;
use shdc::serve::{ModelRegistry, RateLimit, ServeCfg, ServeError, Server, TenantQuota};

fn encoder_cfg(seed: u64) -> EncoderCfg {
    EncoderCfg {
        cat: CatCfg::Bloom { d: 1024, k: 4 },
        num: NumCfg::Sjlt { d: 256, k: 4 },
        bundle: BundleMethod::Concat,
        n_numeric: 13,
        seed,
    }
}

fn data_cfg(seed: u64) -> SyntheticConfig {
    SyntheticConfig { alphabet_size: 20_000, noise: 0.3, ..SyntheticConfig::sampled(seed) }
}

/// Train a quick logistic model offline on the encoded stream (enough
/// steps that scores carry real margins, not initialization noise).
fn train_quick(enc_cfg: &EncoderCfg, data: &SyntheticConfig) -> LogisticModel {
    let mut enc = enc_cfg.build();
    let mut stream = SyntheticStream::new(data.clone());
    let mut model = LogisticModel::new(enc_cfg.out_dim());
    let mut errs = Vec::new();
    let mut records: Vec<Record> = Vec::new();
    let mut encs: Vec<Encoding> = Vec::new();
    let mut labels: Vec<bool> = Vec::new();
    for _ in 0..60 {
        stream.next_batch_into(&mut records, 64);
        enc.encode_batch_into(&records, &mut encs);
        labels.clear();
        labels.extend(records.iter().map(|r| r.label));
        model.sgd_step_parts(&encs, &labels, 0.3, &mut errs);
        enc.recycle_all(encs.drain(..));
    }
    model
}

fn serve_cfg(enc_cfg: EncoderCfg, precision: Precision) -> ServeCfg {
    ServeCfg {
        coordinator: CoordinatorCfg {
            batch_size: 16,
            n_workers: 3,
            queue_depth: 2,
            ..Default::default()
        },
        max_batch_delay: Duration::from_micros(200),
        queue_cap: 64,
        slots: 32,
        precision,
        ..ServeCfg::new(enc_cfg)
    }
}

#[test]
fn served_f32_top1_matches_offline_logistic() {
    let enc_cfg = encoder_cfg(41);
    let data = data_cfg(42);
    let model = train_quick(&enc_cfg, &data);
    let store = AmStore::from_logistic(&model);
    let (server, handle) = Server::new(serve_cfg(enc_cfg.clone(), Precision::F32), store);
    let server_thread = thread::spawn(move || server.run());

    let mut offline_enc = enc_cfg.build();
    let mut stream = SyntheticStream::new(data_cfg(43)); // fresh sample
    let mut checked = 0usize;
    for _ in 0..300 {
        let rec = stream.next_record().unwrap();
        let code = offline_enc.encode(&rec);
        let z = model.score(&code);
        offline_enc.recycle(code);
        let resp = handle.classify(rec).expect("serve");
        if z.abs() < 1e-3 {
            continue; // f32 store vs f64 offline can differ at a tie
        }
        checked += 1;
        assert_eq!(
            resp.top_class == 1,
            z > 0.0,
            "served top-1 disagrees with offline score z={z}"
        );
    }
    assert!(checked >= 250, "margin guard skipped too much ({checked}/300)");
    handle.shutdown();
    let stats = server_thread.join().expect("server").snapshot();
    // The steady-state loop must actually recycle (shells return through
    // the consumer→worker channel, not the allocator).
    assert!(stats.buffers_recycled > 0, "serve loop never recycled: {stats:?}");
    let snap = handle.stats();
    assert_eq!(snap.completed, 300);
    assert!(snap.latency_ns.p99 >= snap.latency_ns.p50);
}

#[test]
fn served_binary_store_matches_naive_unpacked_reference() {
    let enc_cfg = encoder_cfg(51);
    let data = data_cfg(52);
    let model = train_quick(&enc_cfg, &data);
    // Naive reference state: the unpacked ±1 prototype rows.
    let sign = |x: f32| if x >= 0.0 { 1i64 } else { -1 };
    let rows: Vec<Vec<f32>> = vec![
        model.theta.iter().map(|t| -t).collect(),
        model.theta.clone(),
    ];
    let store = AmStore::from_logistic(&model);

    let (server, handle) = Server::new(serve_cfg(enc_cfg.clone(), Precision::Binary), store);
    let server_thread = thread::spawn(move || server.run());

    let mut offline_enc = enc_cfg.build();
    let mut stream = SyntheticStream::new(data_cfg(53));
    for _ in 0..200 {
        let rec = stream.next_record().unwrap();
        let code = offline_enc.encode(&rec);
        // Naive unpacked scoring of this query against both sign rows.
        let naive: Vec<i64> = rows
            .iter()
            .map(|row| match &code {
                Encoding::Dense(q) => {
                    q.iter().zip(row).map(|(&x, &p)| sign(x) * sign(p)).sum()
                }
                Encoding::SparseBinary { indices, .. } => {
                    indices.iter().map(|&i| sign(row[i as usize])).sum()
                }
            })
            .collect();
        offline_enc.recycle(code);
        let want_class =
            if naive[1] > naive[0] { 1u32 } else { 0 }; // ties break low, as in the store
        let want_score = naive[want_class as usize] as f32;

        let resp = handle.classify(rec).expect("serve");
        // Bit-for-bit: integer-valued scores, exact equality.
        assert_eq!(resp.score, want_score, "binary score mismatch");
        assert_eq!(resp.top_class, want_class, "binary top-1 mismatch");
    }
    handle.shutdown();
    server_thread.join().expect("server");
}

#[test]
fn served_int8_store_matches_offline_store_scoring() {
    // The serve path must return exactly what a direct AmStore lookup
    // returns for the int8 representation (same kernels, same scratch
    // discipline) — pins the precision plumbing end to end.
    let enc_cfg = encoder_cfg(61);
    let data = data_cfg(62);
    let model = train_quick(&enc_cfg, &data);
    let store = AmStore::from_logistic(&model);
    let offline_store = store.clone();

    let (server, handle) = Server::new(serve_cfg(enc_cfg.clone(), Precision::Int8), store);
    let server_thread = thread::spawn(move || server.run());

    let mut offline_enc = enc_cfg.build();
    let mut scratch = AmScratch::new();
    let mut stream = SyntheticStream::new(data_cfg(63));
    for _ in 0..150 {
        let rec = stream.next_record().unwrap();
        let code = offline_enc.encode(&rec);
        let (want_class, want_score) = offline_store.top1(&code, Precision::Int8, &mut scratch);
        offline_enc.recycle(code);
        let resp = handle.classify(rec).expect("serve");
        assert_eq!(resp.top_class, want_class);
        assert_eq!(resp.score, want_score);
    }
    handle.shutdown();
    server_thread.join().expect("server");
}

#[test]
fn concurrent_clients_get_their_own_answers() {
    // Correlation under concurrency + stealing: every client checks each
    // response against an offline lookup of the record it submitted.
    let enc_cfg = encoder_cfg(71);
    let model = train_quick(&enc_cfg, &data_cfg(72));
    let store = AmStore::from_logistic(&model);
    let offline_store = Arc::new(store.clone());
    let mut cfg = serve_cfg(enc_cfg.clone(), Precision::F32);
    // Force steals: one slow worker under a multi-client load.
    cfg.coordinator.slow_worker = Some((0, Duration::from_micros(300)));
    let (server, handle) = Server::new(cfg, store);
    let server_thread = thread::spawn(move || server.run());

    let clients: Vec<_> = (0..4)
        .map(|c| {
            let h = handle.clone();
            let enc_cfg = enc_cfg.clone();
            let offline_store = Arc::clone(&offline_store);
            thread::spawn(move || {
                let mut enc = enc_cfg.build();
                let mut scratch = AmScratch::new();
                let mut stream = SyntheticStream::new(data_cfg(80 + c));
                for _ in 0..80 {
                    let rec = stream.next_record().unwrap();
                    let code = enc.encode(&rec);
                    let (want_class, want_score) =
                        offline_store.top1(&code, Precision::F32, &mut scratch);
                    enc.recycle(code);
                    let resp = h.classify(rec).expect("serve");
                    assert_eq!(resp.top_class, want_class);
                    assert_eq!(resp.score, want_score);
                }
            })
        })
        .collect();
    for c in clients {
        c.join().expect("client");
    }
    handle.shutdown();
    let stats = server_thread.join().expect("server").snapshot();
    assert_eq!(handle.stats().completed, 4 * 80);
    assert!(stats.records_encoded == 4 * 80);
}

#[test]
fn many_class_sharded_serve_matches_offline_single_scan() {
    use shdc::data::{ManyClassConfig, ManyClassStream};
    use shdc::serve::build_many_class_store;

    // Pure-categorical encoder: the many-class regime is symbol-driven,
    // and the AM scan over 1000 classes dominates per-request cost.
    let enc_cfg = EncoderCfg {
        cat: CatCfg::Bloom { d: 1024, k: 4 },
        num: NumCfg::None,
        bundle: BundleMethod::Concat,
        n_numeric: 0,
        seed: 91,
    };
    let data = ManyClassConfig::classes(1000, 92);
    let store = build_many_class_store(&enc_cfg, &data);
    let offline_store = store.clone();
    let mut cfg = serve_cfg(enc_cfg.clone(), Precision::F32);
    cfg.am_shards = 7; // ragged partition: 1000 = 6·143 + 142
    let (server, handle) = Server::new(cfg, store);
    let server_thread = thread::spawn(move || server.run());

    let mut offline_enc = enc_cfg.build();
    let mut scratch = AmScratch::new();
    // Salted stream: fresh Zipf draws over the same planted classes the
    // store was built from.
    let mut stream = ManyClassStream::new(ManyClassConfig { stream_salt: 1, ..data.clone() });
    const N: usize = 300;
    let mut recovered = 0usize;
    for _ in 0..N {
        let (rec, class) = stream.next_with_class();
        let code = offline_enc.encode(&rec);
        let (want_class, want_score) = offline_store.top1(&code, Precision::F32, &mut scratch);
        offline_enc.recycle(code);
        let resp = handle.classify(rec).expect("serve");
        // The contract: sharded serve ≡ offline single scan, bit for bit.
        assert_eq!(resp.top_class, want_class, "sharded serve diverged from single scan");
        assert_eq!(resp.score, want_score, "sharded serve score diverged from single scan");
        if resp.top_class == class {
            recovered += 1;
        }
    }
    // Sanity (not the contract): class-keyed symbols dominate the noise,
    // so the planted class is usually recovered.
    assert!(recovered > N / 2, "planted classes mostly lost: {recovered}/{N}");
    handle.shutdown();
    server_thread.join().expect("server");

    let snap = handle.stats();
    assert_eq!(snap.completed, N as u64);
    // Per-shard counters reconcile with the global scan counts: the
    // shard slices partition all 1000 classes, and every shard is
    // scanned exactly once per scored request.
    let shards = &snap.models[0].shards;
    assert_eq!(shards.len(), 7);
    assert_eq!(shards.iter().map(|s| u64::from(s.classes)).sum::<u64>(), 1000);
    assert!(shards.iter().all(|s| s.classes == 142 || s.classes == 143));
    for (i, sh) in shards.iter().enumerate() {
        assert_eq!(sh.scans, N as u64, "shard {i} scan count");
    }
}

/// A second tenant shape: half the categorical width, half the numeric
/// projection (out_dim 640 vs [`encoder_cfg`]'s 1280) — routing bugs
/// that mix models surface as hard dimension mismatches, not subtle
/// score drift.
fn encoder_cfg_narrow(seed: u64) -> EncoderCfg {
    EncoderCfg {
        cat: CatCfg::Bloom { d: 512, k: 3 },
        num: NumCfg::Sjlt { d: 128, k: 4 },
        bundle: BundleMethod::Concat,
        n_numeric: 13,
        seed,
    }
}

#[test]
fn multi_model_routing_matches_per_model_offline() {
    // Two tenants — different dimensionality, seeds and store
    // precisions — behind one registry and one shared worker pool.
    // Interleaved clients must each get answers bit-identical to *their*
    // model's offline encode + top-1.
    let enc_a = encoder_cfg(141);
    let enc_b = encoder_cfg_narrow(151);
    let data = data_cfg(142);
    let store_a = AmStore::from_logistic(&train_quick(&enc_a, &data));
    let store_b = AmStore::from_logistic(&train_quick(&enc_b, &data));
    let offline_a = Arc::new(store_a.clone());
    let offline_b = Arc::new(store_b.clone());

    let mut reg = ModelRegistry::new();
    let a = reg.register(
        "wide-f32",
        enc_a.clone(),
        store_a,
        Precision::F32,
        TenantQuota::default(),
    );
    let b = reg.register(
        "narrow-int8",
        enc_b.clone(),
        store_b,
        Precision::Int8,
        TenantQuota::default(),
    );
    let (server, handle) = Server::with_registry(serve_cfg(enc_a.clone(), Precision::F32), reg);
    let server_thread = thread::spawn(move || server.run());

    let clients: Vec<_> = (0..4)
        .map(|c| {
            let h = handle.clone();
            let (model, enc_cfg, store, precision) = if c % 2 == 0 {
                (a, enc_a.clone(), Arc::clone(&offline_a), Precision::F32)
            } else {
                (b, enc_b.clone(), Arc::clone(&offline_b), Precision::Int8)
            };
            thread::spawn(move || {
                let mut enc = enc_cfg.build();
                let mut scratch = AmScratch::new();
                let mut stream = SyntheticStream::new(data_cfg(160 + c as u64));
                for _ in 0..60 {
                    let rec = stream.next_record().unwrap();
                    let code = enc.encode(&rec);
                    let (want_class, want_score) = store.top1(&code, precision, &mut scratch);
                    enc.recycle(code);
                    let resp = h.classify_for(model, rec).expect("serve");
                    assert_eq!(resp.top_class, want_class, "routed to the wrong model?");
                    assert_eq!(resp.score, want_score, "routed to the wrong model?");
                }
            })
        })
        .collect();
    for c in clients {
        c.join().expect("client");
    }
    handle.shutdown();
    let pstats = server_thread.join().expect("server").snapshot();
    let snap = handle.stats();
    assert_eq!(snap.completed, 240);
    assert_eq!(snap.models[0].name, "wide-f32");
    assert_eq!(snap.models[1].name, "narrow-int8");
    assert_eq!(snap.models[0].completed, 120);
    assert_eq!(snap.models[1].completed, 120);
    // Per-model tallies reconcile with the globals.
    assert_eq!(snap.models.iter().map(|m| m.submitted).sum::<u64>(), snap.submitted);
    assert_eq!(
        snap.batches,
        snap.size_cuts + snap.deadline_cuts + snap.idle_cuts + snap.model_cuts
    );
    // Lazy per-worker×model encoder caches: both models were built at
    // least once, at most once per (worker, model) pair (3 workers × 2).
    assert!(pstats.encoder_builds >= 2, "encoder cache never populated: {pstats:?}");
    assert!(pstats.encoder_builds <= 6, "encoder cache thrashing: {pstats:?}");
}

#[test]
fn multi_model_batches_cut_at_model_boundaries() {
    let enc_a = encoder_cfg(171);
    let enc_b = encoder_cfg_narrow(181);
    let data = data_cfg(172);
    let store_a = AmStore::from_logistic(&train_quick(&enc_a, &data));
    let store_b = AmStore::from_logistic(&train_quick(&enc_b, &data));
    let mut reg = ModelRegistry::new();
    let a = reg.register("a", enc_a.clone(), store_a, Precision::F32, TenantQuota::default());
    let b = reg.register("b", enc_b, store_b, Precision::F32, TenantQuota::default());
    let (server, handle) = Server::with_registry(serve_cfg(enc_a, Precision::F32), reg);

    // Queue a mixed-model backlog BEFORE the server starts consuming
    // (submissions land in the bounded queue without a running batcher),
    // so the first gather deterministically sees both models and must
    // stop at the first model boundary: encode batches are
    // model-homogeneous.
    let clients: Vec<_> = (0..6)
        .map(|c| {
            let h = handle.clone();
            let model = if c % 2 == 0 { a } else { b };
            thread::spawn(move || {
                let mut stream = SyntheticStream::new(data_cfg(190 + c as u64));
                let rec = stream.next_record().unwrap();
                h.classify_for(model, rec).expect("serve")
            })
        })
        .collect();
    // `submitted` ticks under the queue lock at enqueue time.
    let t0 = std::time::Instant::now();
    while handle.stats().submitted < 6 {
        assert!(t0.elapsed() < Duration::from_secs(10), "submissions never queued");
        thread::sleep(Duration::from_millis(1));
    }
    let server_thread = thread::spawn(move || server.run());
    for c in clients {
        c.join().expect("client");
    }
    handle.shutdown();
    server_thread.join().expect("server");
    let snap = handle.stats();
    assert_eq!(snap.completed, 6);
    assert!(snap.model_cuts >= 1, "mixed queue produced no model cuts: {snap:?}");
    assert_eq!(
        snap.batches,
        snap.size_cuts + snap.deadline_cuts + snap.idle_cuts + snap.model_cuts
    );
}

#[test]
fn multi_model_quota_sheds_hostile_tenant_not_quiet_one() {
    let enc_a = encoder_cfg(201);
    let enc_b = encoder_cfg_narrow(211);
    let data = data_cfg(202);
    let store_a = AmStore::from_logistic(&train_quick(&enc_a, &data));
    let store_b = AmStore::from_logistic(&train_quick(&enc_b, &data));

    // Solo baseline: the quiet tenant's workload alone on an identical
    // single-model server (the fairness yardstick).
    let solo_p99 = {
        let (server, handle) =
            Server::new(serve_cfg(enc_a.clone(), Precision::F32), store_a.clone());
        let t = thread::spawn(move || server.run());
        let mut stream = SyntheticStream::new(data_cfg(203));
        for _ in 0..100 {
            handle.classify(stream.next_record().unwrap()).expect("solo serve");
        }
        handle.shutdown();
        t.join().expect("server");
        handle.stats().latency_ns.p99
    };

    // The hostile tenant's bucket holds 3 tokens and refills at 1e-3
    // rps — effectively never over a test run — so exactly `burst`
    // requests are admitted and everything after sheds fail-fast.
    let mut reg = ModelRegistry::new();
    let quiet = reg.register(
        "quiet",
        enc_a.clone(),
        store_a.clone(),
        Precision::F32,
        TenantQuota::default(),
    );
    let hostile = reg.register(
        "hostile",
        enc_b,
        store_b,
        Precision::Int8,
        TenantQuota { max_in_flight: None, rate: Some(RateLimit { rps: 1e-3, burst: 3.0 }) },
    );
    let (server, handle) = Server::with_registry(serve_cfg(enc_a.clone(), Precision::F32), reg);
    let server_thread = thread::spawn(move || server.run());

    let hostile_thread = {
        let h = handle.clone();
        thread::spawn(move || {
            let mut stream = SyntheticStream::new(data_cfg(204));
            let (mut ok, mut shed) = (0u64, 0u64);
            for _ in 0..40 {
                match h.classify_for(hostile, stream.next_record().unwrap()) {
                    Ok(_) => ok += 1,
                    Err(ServeError::QuotaExceeded) => shed += 1,
                    Err(e) => panic!("hostile tenant saw unexpected error: {e}"),
                }
            }
            (ok, shed)
        })
    };
    // The quiet tenant runs its full offline cross-check concurrently;
    // the hostile flood must not cost it a single error.
    let offline = Arc::new(store_a);
    let quiet_thread = {
        let h = handle.clone();
        let enc_cfg = enc_a.clone();
        let offline = Arc::clone(&offline);
        thread::spawn(move || {
            let mut enc = enc_cfg.build();
            let mut scratch = AmScratch::new();
            let mut stream = SyntheticStream::new(data_cfg(203)); // same load as solo
            for _ in 0..100 {
                let rec = stream.next_record().unwrap();
                let code = enc.encode(&rec);
                let (want_class, want_score) = offline.top1(&code, Precision::F32, &mut scratch);
                enc.recycle(code);
                let resp = h.classify_for(quiet, rec).expect("quiet tenant must never shed");
                assert_eq!(resp.top_class, want_class);
                assert_eq!(resp.score, want_score);
            }
        })
    };
    let (hostile_ok, hostile_shed) = hostile_thread.join().expect("hostile client");
    quiet_thread.join().expect("quiet client");
    handle.shutdown();
    server_thread.join().expect("server");

    let snap = handle.stats();
    // Exactly the burst admitted; the rest refused by the quota alone.
    assert_eq!(hostile_ok, 3);
    assert_eq!(hostile_shed, 37);
    let hm = &snap.models[hostile.0 as usize];
    assert_eq!(hm.quota_shed, 37);
    assert_eq!(hm.submitted, 3);
    assert_eq!(hm.completed, 3);
    let qm = &snap.models[quiet.0 as usize];
    assert_eq!(qm.completed, 100);
    assert_eq!(qm.quota_shed + qm.rejected + qm.shed + qm.expired + qm.failed, 0);
    assert_eq!(snap.quota_shed, 37);
    assert!(snap.shed_rate() > 0.0);
    // Fairness: quota refusals never touch the queue and only 3 hostile
    // requests were ever admitted, so the quiet tenant's tail must stay
    // within a generous multiple of its solo baseline (floor 5 ms
    // absorbs scheduler noise on loaded CI hosts).
    let bound = solo_p99.max(5_000_000) * 40;
    assert!(
        qm.latency_ns.p99 <= bound,
        "quiet p99 {} vs solo {} (bound {})",
        qm.latency_ns.p99,
        solo_p99,
        bound
    );
}
