//! End-to-end smoke test for the inference serving subsystem:
//! submit → micro-batch → encode (work-stealing pipeline) → AM score →
//! respond, checked against offline references.
//!
//! Acceptance contract (ISSUE 5):
//! * the **f32** store's served top-1 equals offline
//!   [`LogisticModel`] scoring (sign of θ·φ + b) — margin-guarded
//!   against f32-vs-f64 accumulation for near-zero scores;
//! * the **binarized** store agrees with a naive unpacked ±1 reference
//!   **bit-for-bit** (integer scores, no tolerance);
//! * the steady-state serve loop recycles its buffers (asserted via the
//!   pipeline recycle counters here; the allocation-counter harness in
//!   `tests/alloc_regression.rs` pins the stronger zero-alloc claim).

use std::sync::Arc;
use std::thread;
use std::time::Duration;

use shdc::am::{AmScratch, AmStore, Precision};
use shdc::coordinator::{CatCfg, CoordinatorCfg, EncoderCfg, NumCfg};
use shdc::data::synthetic::SyntheticConfig;
use shdc::data::{Record, RecordStream, SyntheticStream};
use shdc::encoding::{BundleMethod, Encoding};
use shdc::model::LogisticModel;
use shdc::serve::{ServeCfg, Server};

fn encoder_cfg(seed: u64) -> EncoderCfg {
    EncoderCfg {
        cat: CatCfg::Bloom { d: 1024, k: 4 },
        num: NumCfg::Sjlt { d: 256, k: 4 },
        bundle: BundleMethod::Concat,
        n_numeric: 13,
        seed,
    }
}

fn data_cfg(seed: u64) -> SyntheticConfig {
    SyntheticConfig { alphabet_size: 20_000, noise: 0.3, ..SyntheticConfig::sampled(seed) }
}

/// Train a quick logistic model offline on the encoded stream (enough
/// steps that scores carry real margins, not initialization noise).
fn train_quick(enc_cfg: &EncoderCfg, data: &SyntheticConfig) -> LogisticModel {
    let mut enc = enc_cfg.build();
    let mut stream = SyntheticStream::new(data.clone());
    let mut model = LogisticModel::new(enc_cfg.out_dim());
    let mut errs = Vec::new();
    let mut records: Vec<Record> = Vec::new();
    let mut encs: Vec<Encoding> = Vec::new();
    let mut labels: Vec<bool> = Vec::new();
    for _ in 0..60 {
        stream.next_batch_into(&mut records, 64);
        enc.encode_batch_into(&records, &mut encs);
        labels.clear();
        labels.extend(records.iter().map(|r| r.label));
        model.sgd_step_parts(&encs, &labels, 0.3, &mut errs);
        enc.recycle_all(encs.drain(..));
    }
    model
}

fn serve_cfg(enc_cfg: EncoderCfg, precision: Precision) -> ServeCfg {
    ServeCfg {
        coordinator: CoordinatorCfg {
            batch_size: 16,
            n_workers: 3,
            queue_depth: 2,
            ..Default::default()
        },
        max_batch_delay: Duration::from_micros(200),
        queue_cap: 64,
        slots: 32,
        precision,
        ..ServeCfg::new(enc_cfg)
    }
}

#[test]
fn served_f32_top1_matches_offline_logistic() {
    let enc_cfg = encoder_cfg(41);
    let data = data_cfg(42);
    let model = train_quick(&enc_cfg, &data);
    let store = AmStore::from_logistic(&model);
    let (server, handle) = Server::new(serve_cfg(enc_cfg.clone(), Precision::F32), store);
    let server_thread = thread::spawn(move || server.run());

    let mut offline_enc = enc_cfg.build();
    let mut stream = SyntheticStream::new(data_cfg(43)); // fresh sample
    let mut checked = 0usize;
    for _ in 0..300 {
        let rec = stream.next_record().unwrap();
        let code = offline_enc.encode(&rec);
        let z = model.score(&code);
        offline_enc.recycle(code);
        let resp = handle.classify(rec).expect("serve");
        if z.abs() < 1e-3 {
            continue; // f32 store vs f64 offline can differ at a tie
        }
        checked += 1;
        assert_eq!(
            resp.top_class == 1,
            z > 0.0,
            "served top-1 disagrees with offline score z={z}"
        );
    }
    assert!(checked >= 250, "margin guard skipped too much ({checked}/300)");
    handle.shutdown();
    let stats = server_thread.join().expect("server").snapshot();
    // The steady-state loop must actually recycle (shells return through
    // the consumer→worker channel, not the allocator).
    assert!(stats.buffers_recycled > 0, "serve loop never recycled: {stats:?}");
    let snap = handle.stats();
    assert_eq!(snap.completed, 300);
    assert!(snap.latency_ns.p99 >= snap.latency_ns.p50);
}

#[test]
fn served_binary_store_matches_naive_unpacked_reference() {
    let enc_cfg = encoder_cfg(51);
    let data = data_cfg(52);
    let model = train_quick(&enc_cfg, &data);
    // Naive reference state: the unpacked ±1 prototype rows.
    let sign = |x: f32| if x >= 0.0 { 1i64 } else { -1 };
    let rows: Vec<Vec<f32>> = vec![
        model.theta.iter().map(|t| -t).collect(),
        model.theta.clone(),
    ];
    let store = AmStore::from_logistic(&model);

    let (server, handle) = Server::new(serve_cfg(enc_cfg.clone(), Precision::Binary), store);
    let server_thread = thread::spawn(move || server.run());

    let mut offline_enc = enc_cfg.build();
    let mut stream = SyntheticStream::new(data_cfg(53));
    for _ in 0..200 {
        let rec = stream.next_record().unwrap();
        let code = offline_enc.encode(&rec);
        // Naive unpacked scoring of this query against both sign rows.
        let naive: Vec<i64> = rows
            .iter()
            .map(|row| match &code {
                Encoding::Dense(q) => {
                    q.iter().zip(row).map(|(&x, &p)| sign(x) * sign(p)).sum()
                }
                Encoding::SparseBinary { indices, .. } => {
                    indices.iter().map(|&i| sign(row[i as usize])).sum()
                }
            })
            .collect();
        offline_enc.recycle(code);
        let want_class =
            if naive[1] > naive[0] { 1u32 } else { 0 }; // ties break low, as in the store
        let want_score = naive[want_class as usize] as f32;

        let resp = handle.classify(rec).expect("serve");
        // Bit-for-bit: integer-valued scores, exact equality.
        assert_eq!(resp.score, want_score, "binary score mismatch");
        assert_eq!(resp.top_class, want_class, "binary top-1 mismatch");
    }
    handle.shutdown();
    server_thread.join().expect("server");
}

#[test]
fn served_int8_store_matches_offline_store_scoring() {
    // The serve path must return exactly what a direct AmStore lookup
    // returns for the int8 representation (same kernels, same scratch
    // discipline) — pins the precision plumbing end to end.
    let enc_cfg = encoder_cfg(61);
    let data = data_cfg(62);
    let model = train_quick(&enc_cfg, &data);
    let store = AmStore::from_logistic(&model);
    let offline_store = store.clone();

    let (server, handle) = Server::new(serve_cfg(enc_cfg.clone(), Precision::Int8), store);
    let server_thread = thread::spawn(move || server.run());

    let mut offline_enc = enc_cfg.build();
    let mut scratch = AmScratch::new();
    let mut stream = SyntheticStream::new(data_cfg(63));
    for _ in 0..150 {
        let rec = stream.next_record().unwrap();
        let code = offline_enc.encode(&rec);
        let (want_class, want_score) = offline_store.top1(&code, Precision::Int8, &mut scratch);
        offline_enc.recycle(code);
        let resp = handle.classify(rec).expect("serve");
        assert_eq!(resp.top_class, want_class);
        assert_eq!(resp.score, want_score);
    }
    handle.shutdown();
    server_thread.join().expect("server");
}

#[test]
fn concurrent_clients_get_their_own_answers() {
    // Correlation under concurrency + stealing: every client checks each
    // response against an offline lookup of the record it submitted.
    let enc_cfg = encoder_cfg(71);
    let model = train_quick(&enc_cfg, &data_cfg(72));
    let store = AmStore::from_logistic(&model);
    let offline_store = Arc::new(store.clone());
    let mut cfg = serve_cfg(enc_cfg.clone(), Precision::F32);
    // Force steals: one slow worker under a multi-client load.
    cfg.coordinator.slow_worker = Some((0, Duration::from_micros(300)));
    let (server, handle) = Server::new(cfg, store);
    let server_thread = thread::spawn(move || server.run());

    let clients: Vec<_> = (0..4)
        .map(|c| {
            let h = handle.clone();
            let enc_cfg = enc_cfg.clone();
            let offline_store = Arc::clone(&offline_store);
            thread::spawn(move || {
                let mut enc = enc_cfg.build();
                let mut scratch = AmScratch::new();
                let mut stream = SyntheticStream::new(data_cfg(80 + c));
                for _ in 0..80 {
                    let rec = stream.next_record().unwrap();
                    let code = enc.encode(&rec);
                    let (want_class, want_score) =
                        offline_store.top1(&code, Precision::F32, &mut scratch);
                    enc.recycle(code);
                    let resp = h.classify(rec).expect("serve");
                    assert_eq!(resp.top_class, want_class);
                    assert_eq!(resp.score, want_score);
                }
            })
        })
        .collect();
    for c in clients {
        c.join().expect("client");
    }
    handle.shutdown();
    let stats = server_thread.join().expect("server").snapshot();
    assert_eq!(handle.stats().completed, 4 * 80);
    assert!(stats.records_encoded == 4 * 80);
}
