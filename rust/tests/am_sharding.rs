//! Differential suite for the sharded associative-memory scan: every
//! result of [`ShardedAmStore`] must be **exactly equal** — same class
//! ids, same score bits, same order — to the single-thread
//! [`AmStore`] scan, across precision × shard count × class count,
//! including ragged last shards, `k` larger than a shard, and
//! constructed score ties straddling shard boundaries. The merge's
//! tie-break contract (score descending, lowest class id first among
//! equal scores) is pinned here, as is scorer-count invariance — the
//! thread cap partitions work, never results.

use shdc::am::{AmScratch, AmStore, Precision, ShardScratch, ShardedAmStore};
use shdc::encoding::{sparse_from_indices, Encoding};
use shdc::util::rng::Rng;

fn random_store(n_classes: usize, d: usize, seed: u64, biases: bool) -> AmStore {
    let mut rng = Rng::new(seed);
    let rows: Vec<Vec<f32>> = (0..n_classes)
        .map(|_| (0..d).map(|_| rng.normal_f32()).collect())
        .collect();
    let b: Vec<f32>;
    let biases = if biases {
        b = (0..n_classes).map(|_| rng.normal_f32() * 0.1).collect();
        Some(&b[..])
    } else {
        None
    };
    AmStore::from_prototypes(d, &rows, biases)
}

fn dense_query(d: usize, rng: &mut Rng) -> Encoding {
    Encoding::Dense((0..d).map(|_| rng.normal_f32()).collect())
}

fn sparse_query(d: usize, rng: &mut Rng) -> Encoding {
    let idx: Vec<u32> = (0..1 + rng.below_usize(d / 2))
        .map(|_| rng.below(d as u64) as u32)
        .collect();
    sparse_from_indices(idx, d)
}

/// Element-for-element equality with bitwise score comparison — the
/// acceptance criterion is *exact* equality, not approximate.
fn assert_results_identical(got: &[(u32, f32)], want: &[(u32, f32)], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert_eq!(g.0, w.0, "{ctx}: class at rank {i}");
        assert_eq!(g.1.to_bits(), w.1.to_bits(), "{ctx}: score bits at rank {i}");
    }
}

/// The core matrix: precisions {f32, int8, binarized} × shard counts
/// {1, 2, 7, n_classes} × class counts {2, 100}, dense and sparse
/// queries, top-1 and top-k (k below, at, and above n_classes).
#[test]
fn sharded_scan_equals_single_scan_across_matrix() {
    let mut rng = Rng::new(0xa51);
    for &n_classes in &[2usize, 100] {
        let d = 48;
        let store = random_store(n_classes, d, 11 + n_classes as u64, true);
        let queries: Vec<Encoding> = (0..3)
            .map(|_| dense_query(d, &mut rng))
            .chain((0..3).map(|_| sparse_query(d, &mut rng)))
            .collect();
        for &n_shards in &[1usize, 2, 7, n_classes] {
            let sharded = ShardedAmStore::new(store.clone(), n_shards);
            assert_eq!(sharded.n_shards(), n_shards.clamp(1, n_classes));
            let mut single = AmScratch::new();
            let mut scratch = ShardScratch::new();
            let (mut got, mut want) = (Vec::new(), Vec::new());
            for (qi, q) in queries.iter().enumerate() {
                for prec in Precision::ALL {
                    let ctx = format!("classes={n_classes} shards={n_shards} q={qi} {prec:?}");
                    assert_eq!(
                        sharded.top1(q, prec, &mut scratch),
                        store.top1(q, prec, &mut single),
                        "{ctx}: top1"
                    );
                    for k in [1usize, 3, n_classes, n_classes + 5] {
                        store.topk_into(q, prec, k, &mut single, &mut want);
                        sharded.topk_into(q, prec, k, &mut scratch, &mut got);
                        assert_eq!(want.len(), k.clamp(1, n_classes), "{ctx}: k={k} clamp");
                        assert_results_identical(&got, &want, &format!("{ctx} k={k}"));
                    }
                }
            }
        }
    }
}

/// Many-class scale point of the matrix: 5k classes, enough shards that
/// every scorer thread owns several, plus per-class shards.
#[test]
fn five_thousand_classes_match_single_scan() {
    let n_classes = 5_000;
    let d = 64;
    let store = random_store(n_classes, d, 77, false);
    let mut rng = Rng::new(0xbeef);
    let queries = [dense_query(d, &mut rng), sparse_query(d, &mut rng)];
    let mut single = AmScratch::new();
    let (mut got, mut want) = (Vec::new(), Vec::new());
    for &n_shards in &[7usize, 64, n_classes] {
        let sharded = ShardedAmStore::new(store.clone(), n_shards);
        let mut scratch = ShardScratch::new();
        for q in &queries {
            for prec in Precision::ALL {
                let ctx = format!("shards={n_shards} {prec:?}");
                assert_eq!(
                    sharded.top1(q, prec, &mut scratch),
                    store.top1(q, prec, &mut single),
                    "{ctx}: top1"
                );
                store.topk_into(q, prec, 17, &mut single, &mut want);
                sharded.topk_into(q, prec, 17, &mut scratch, &mut got);
                assert_results_identical(&got, &want, &ctx);
            }
        }
    }
}

/// Ragged partitions (10 classes over 3 shards → 4 + 3 + 3) with `k`
/// larger than any one shard, and `k` larger than the class count
/// (clamped to n_classes, same as the single scan).
#[test]
fn ragged_shards_and_k_exceeding_shard_size() {
    let n_classes = 10;
    let d = 24;
    let store = random_store(n_classes, d, 13, true);
    let sharded = ShardedAmStore::new(store.clone(), 3);
    assert_eq!(sharded.shard_range(0), 0..4);
    assert_eq!(sharded.shard_range(1), 4..7);
    assert_eq!(sharded.shard_range(2), 7..10);
    let mut rng = Rng::new(14);
    let q = dense_query(d, &mut rng);
    let mut single = AmScratch::new();
    let mut scratch = ShardScratch::new();
    let (mut got, mut want) = (Vec::new(), Vec::new());
    for prec in Precision::ALL {
        // k = 7 exceeds every shard (max shard is 4 classes); the merge
        // must interleave all three shard lists.
        store.topk_into(&q, prec, 7, &mut single, &mut want);
        sharded.topk_into(&q, prec, 7, &mut scratch, &mut got);
        assert_results_identical(&got, &want, &format!("{prec:?} k=7"));
        // k = 23 > n_classes clamps to the full ranking.
        store.topk_into(&q, prec, 23, &mut single, &mut want);
        sharded.topk_into(&q, prec, 23, &mut scratch, &mut got);
        assert_eq!(got.len(), n_classes, "{prec:?}: k>n clamp");
        assert_results_identical(&got, &want, &format!("{prec:?} k=23"));
        // k = 0 clamps up to 1 on both paths.
        store.topk_into(&q, prec, 0, &mut single, &mut want);
        sharded.topk_into(&q, prec, 0, &mut scratch, &mut got);
        assert_eq!(got.len(), 1, "{prec:?}: k=0 clamp");
        assert_results_identical(&got, &want, &format!("{prec:?} k=0"));
    }
}

/// Constructed ties: identical prototype rows make every class score
/// exactly equal in every precision, so the ordering is *pure*
/// tie-break. The lowest class id must win top-1 and the top-k list
/// must come out in ascending class order — for every shard count, with
/// ties straddling every shard boundary.
#[test]
fn tie_break_is_lowest_class_id_across_shard_boundaries() {
    let d = 32;
    let n_classes = 6;
    let mut rng = Rng::new(15);
    let row: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
    let rows: Vec<Vec<f32>> = (0..n_classes).map(|_| row.clone()).collect();
    let store = AmStore::from_prototypes(d, &rows, None);
    let queries = [dense_query(d, &mut rng), sparse_query(d, &mut rng)];
    let mut single = AmScratch::new();
    let (mut got, mut want) = (Vec::new(), Vec::new());
    for &n_shards in &[1usize, 2, 3, 6] {
        let sharded = ShardedAmStore::new(store.clone(), n_shards);
        let mut scratch = ShardScratch::new();
        for q in &queries {
            for prec in Precision::ALL {
                let ctx = format!("shards={n_shards} {prec:?}");
                let (class, score) = sharded.top1(q, prec, &mut scratch);
                assert_eq!(class, 0, "{ctx}: tie must break to class 0");
                sharded.topk_into(q, prec, n_classes, &mut scratch, &mut got);
                let classes: Vec<u32> = got.iter().map(|&(c, _)| c).collect();
                assert_eq!(classes, vec![0, 1, 2, 3, 4, 5], "{ctx}: tie order");
                assert!(
                    got.iter().all(|&(_, s)| s.to_bits() == score.to_bits()),
                    "{ctx}: tied scores must be identical"
                );
                store.topk_into(q, prec, n_classes, &mut single, &mut want);
                assert_results_identical(&got, &want, &ctx);
            }
        }
    }
}

/// Two-group ties: interleaved duplicate rows (even classes share row A,
/// odd classes row B) force the merge to alternate between shards while
/// preserving ascending class order within each equal-score group.
#[test]
fn grouped_ties_interleave_in_class_order() {
    let d = 16;
    let row_a = vec![1.0f32; d];
    let row_b = vec![-1.0f32; d];
    let rows: Vec<Vec<f32>> = (0..6).map(|c| if c % 2 == 0 { row_a.clone() } else { row_b.clone() }).collect();
    let store = AmStore::from_prototypes(d, &rows, None);
    let q = Encoding::Dense(vec![1.0f32; d]);
    let mut single = AmScratch::new();
    let (mut got, mut want) = (Vec::new(), Vec::new());
    for &n_shards in &[1usize, 2, 3, 6] {
        let sharded = ShardedAmStore::new(store.clone(), n_shards);
        let mut scratch = ShardScratch::new();
        for prec in Precision::ALL {
            let ctx = format!("shards={n_shards} {prec:?}");
            sharded.topk_into(&q, prec, 6, &mut scratch, &mut got);
            let classes: Vec<u32> = got.iter().map(|&(c, _)| c).collect();
            // Row A scores strictly above row B on the all-ones query in
            // every precision; within each group, ascending class ids.
            assert_eq!(classes, vec![0, 2, 4, 1, 3, 5], "{ctx}: group interleave");
            store.topk_into(&q, prec, 6, &mut single, &mut want);
            assert_results_identical(&got, &want, &ctx);
        }
    }
}

/// The scorer-thread cap is a parallelism knob only: any cap (fewer,
/// equal, or more than the shard count) yields identical results.
#[test]
fn scorer_count_never_changes_results() {
    let n_classes = 100;
    let d = 32;
    let store = random_store(n_classes, d, 21, true);
    let mut rng = Rng::new(22);
    let q = dense_query(d, &mut rng);
    let mut single = AmScratch::new();
    let mut want = Vec::new();
    store.topk_into(&q, Precision::F32, 12, &mut single, &mut want);
    let want_top1 = store.top1(&q, Precision::F32, &mut single);
    for &scorers in &[1usize, 2, 5, 64] {
        let sharded = ShardedAmStore::with_scorers(store.clone(), 7, scorers);
        let mut scratch = ShardScratch::new();
        let mut got = Vec::new();
        sharded.topk_into(&q, Precision::F32, 12, &mut scratch, &mut got);
        assert_results_identical(&got, &want, &format!("scorers={scorers}"));
        assert_eq!(sharded.top1(&q, Precision::F32, &mut scratch), want_top1);
    }
}

/// The serve consumer's batch path: query-major results equal to the
/// single-scan top-1 of each query, for mixed dense/sparse batches in
/// every precision.
#[test]
fn batch_top1_equals_single_scan_per_query() {
    let n_classes = 100;
    let d = 32;
    let store = random_store(n_classes, d, 31, true);
    let mut rng = Rng::new(32);
    let encs: Vec<Encoding> = (0..5)
        .map(|_| dense_query(d, &mut rng))
        .chain((0..4).map(|_| sparse_query(d, &mut rng)))
        .collect();
    let mut single = AmScratch::new();
    for &n_shards in &[1usize, 4] {
        let sharded = ShardedAmStore::new(store.clone(), n_shards);
        let mut scratch = ShardScratch::new();
        let mut out = Vec::new();
        for prec in Precision::ALL {
            sharded.top1_batch_into(&encs, prec, &mut scratch, &mut out);
            assert_eq!(out.len(), encs.len());
            for (qi, (q, &(class, score))) in encs.iter().zip(&out).enumerate() {
                let (wc, ws) = store.top1(q, prec, &mut single);
                assert_eq!(class, wc, "shards={n_shards} {prec:?} q={qi}");
                assert_eq!(
                    score.to_bits(),
                    ws.to_bits(),
                    "shards={n_shards} {prec:?} q={qi}: score bits"
                );
            }
        }
        // An empty batch is a no-op, not a panic.
        sharded.top1_batch_into(&[], Precision::F32, &mut scratch, &mut out);
        assert!(out.is_empty());
    }
}
