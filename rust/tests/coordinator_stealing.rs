//! Scheduler determinism suite for the work-stealing coordinator.
//!
//! The paper's guarantees (Thomas et al., arXiv 2209.09868; Thomas,
//! Dasgupta & Rosing, arXiv 2010.07426) assume the encoding is a pure
//! function of the input, so the scheduler may move batches between
//! workers *arbitrarily* — steals, injector overflow, slow workers —
//! without changing a single output bit. This suite drives the
//! coordinator through adversarial skew (whale-heavy ragged batches),
//! tiny and large queue depths, 1/3/8 workers, and forced-steal
//! scenarios (the `slow_worker` injection hook), asserting bitwise
//! identity against the single-worker run every time.
//!
//! (`pipeline_ragged_skew_worker_count_invariant` in
//! `scratch_equivalence.rs` is the original, unchanged regression guard;
//! this file is the stealing-specific superset.)

use std::time::Duration;

use shdc::coordinator::{run_pipeline, CatCfg, CoordinatorCfg, EncoderCfg, NumCfg};
use shdc::data::{Record, RecordStream};
use shdc::encoding::{BundleMethod, Encoding};
use shdc::util::rng::mix64;

/// Deterministic stream with *heavily ragged* categorical sets: every
/// 16th record is a whale (hundreds of symbols) and every 64th a
/// mega-whale, the rest carry 0–3 symbols. With a small batch size,
/// whole batches end up orders of magnitude more expensive than their
/// neighbors — the skew regime work stealing exists for.
struct WhaleStream {
    i: u64,
    remaining: u64,
}

impl WhaleStream {
    fn new(n: u64) -> WhaleStream {
        WhaleStream { i: 0, remaining: n }
    }
}

impl RecordStream for WhaleStream {
    fn next_record(&mut self) -> Option<Record> {
        if self.remaining == 0 {
            return None;
        }
        self.remaining -= 1;
        let i = self.i;
        self.i += 1;
        let s = if i % 64 == 0 {
            900
        } else if i % 16 == 0 {
            350
        } else {
            (i % 4) as usize
        };
        let symbols: Vec<u64> = (0..s as u64)
            .map(|j| mix64(i.wrapping_mul(1_000_003) ^ j))
            .collect();
        let numeric: Vec<f32> =
            (0..13u64).map(|j| (((i * 13 + j) % 97) as f32) * 0.11 - 5.0).collect();
        Some(Record { numeric, symbols, label: i % 3 == 0 })
    }
}

fn enc_cfg() -> EncoderCfg {
    EncoderCfg {
        cat: CatCfg::Bloom { d: 1024, k: 4 },
        num: NumCfg::Sjlt { d: 256, k: 4 },
        bundle: BundleMethod::Concat,
        n_numeric: 13,
        seed: 0xacce,
    }
}

/// Run the pipeline over `records` whale records and collect the full
/// output (encodings + labels + batch seqs) plus the stats snapshot.
fn collect(
    records: u64,
    workers: usize,
    queue_depth: usize,
    slow_worker: Option<(usize, Duration)>,
) -> ((Vec<Encoding>, Vec<bool>, Vec<u64>), shdc::coordinator::StatsSnapshot) {
    let stream = WhaleStream::new(records);
    let mut encs = Vec::new();
    let mut labels = Vec::new();
    let mut seqs = Vec::new();
    let stats = run_pipeline(
        stream,
        &enc_cfg(),
        &CoordinatorCfg {
            batch_size: 8,
            n_workers: workers,
            queue_depth,
            max_records: Some(records),
            slow_worker,
            ..Default::default()
        },
        |b| {
            seqs.push(b.seq);
            encs.extend(b.encodings.drain(..));
            labels.extend(b.labels.drain(..));
            true
        },
    );
    ((encs, labels, seqs), stats.snapshot())
}

#[test]
fn skewed_output_invariant_across_workers_and_depths() {
    // The core determinism matrix: worker counts 1/3/8 × queue depths
    // {1, 2, 32} must all be bit-identical to the single-worker run.
    let records = 600u64;
    let (baseline, _) = collect(records, 1, 8, None);
    assert_eq!(baseline.0.len(), records as usize, "stream must deliver every record");
    for workers in [1usize, 3, 8] {
        for depth in [1usize, 2, 32] {
            let (got, snap) = collect(records, workers, depth, None);
            assert_eq!(
                got, baseline,
                "{workers}-worker depth-{depth} run diverged from single-worker"
            );
            assert_eq!(snap.records_encoded, records);
            assert_eq!(snap.records_read, records);
        }
    }
}

#[test]
fn forced_steals_leave_output_bit_identical() {
    // Stall one worker hard enough that its deque *must* be robbed, and
    // check both that steals actually happened and that they are
    // invisible in the output.
    let records = 480u64;
    let (baseline, _) = collect(records, 1, 8, None);
    for (slow_wid, workers) in [(0usize, 3usize), (2, 8)] {
        let slow = Some((slow_wid, Duration::from_millis(3)));
        let (got, snap) = collect(records, workers, 2, slow);
        assert_eq!(
            got, baseline,
            "steals from slow worker {slow_wid}/{workers} changed the output"
        );
        assert!(
            snap.batches_stolen > 0,
            "slow worker {slow_wid}/{workers} was never robbed: {snap:?}"
        );
    }
}

#[test]
fn forced_steals_with_tiny_queue_use_injector() {
    // queue_depth=1 + a stalled worker: its single slot fills instantly,
    // so overflow must route through the injector — and the output still
    // must not move.
    let records = 320u64;
    let (baseline, _) = collect(records, 1, 8, None);
    let (got, snap) = collect(records, 4, 1, Some((0, Duration::from_millis(2))));
    assert_eq!(got, baseline, "injector overflow changed the output");
    assert!(
        snap.injector_batches > 0,
        "depth-1 queues with a stalled worker never overflowed: {snap:?}"
    );
}

#[test]
fn consumer_sees_stream_order_under_steals() {
    let (out, _) = collect(400, 8, 1, Some((1, Duration::from_millis(1))));
    let seqs = out.2;
    let mut sorted = seqs.clone();
    sorted.sort();
    assert_eq!(seqs, sorted, "reorderer must deliver stream order under steals");
    assert_eq!(seqs.len(), 50, "400 records / batch 8");
}

#[test]
fn early_stop_under_forced_steals_unwinds_cleanly() {
    // Stop after 5 batches while a worker is stalled: the reader, parked
    // siblings and the stalled worker must all unwind (no deadlock, no
    // panic), which `run_pipeline` proves by returning at all.
    let stream = WhaleStream::new(100_000);
    let mut batches = 0usize;
    run_pipeline(
        stream,
        &enc_cfg(),
        &CoordinatorCfg {
            batch_size: 8,
            n_workers: 4,
            queue_depth: 2,
            max_records: Some(100_000),
            slow_worker: Some((0, Duration::from_millis(2))),
            ..Default::default()
        },
        |_| {
            batches += 1;
            batches < 5
        },
    );
    assert_eq!(batches, 5);
}

#[test]
fn keep_records_survives_stealing() {
    // Raw records must stay aligned with their encodings no matter which
    // worker encoded the batch.
    let stream = WhaleStream::new(240);
    let mut n = 0usize;
    run_pipeline(
        stream,
        &enc_cfg(),
        &CoordinatorCfg {
            batch_size: 8,
            n_workers: 3,
            queue_depth: 2,
            keep_records: true,
            max_records: Some(240),
            slow_worker: Some((1, Duration::from_millis(1))),
            ..Default::default()
        },
        |b| {
            let recs = b.records.as_ref().expect("records kept");
            assert_eq!(recs.len(), b.encodings.len());
            assert_eq!(recs.len(), b.labels.len());
            for (r, y) in recs.iter().zip(b.labels.iter()) {
                assert_eq!(r.label, *y, "labels must track their records");
            }
            n += recs.len();
            true
        },
    );
    assert_eq!(n, 240);
}

#[test]
fn recycling_round_trips_under_skew() {
    // A *borrowing* consumer (leaves the batch intact, unlike `collect`,
    // which drains and therefore opts out of recycling) keeps the pools
    // warm even while batches hop between workers; after enough batches
    // the recycle counter must be well past zero.
    let records = 1600u64;
    let stream = WhaleStream::new(records);
    let mut n = 0usize;
    let stats = run_pipeline(
        stream,
        &enc_cfg(),
        &CoordinatorCfg {
            batch_size: 8,
            n_workers: 3,
            queue_depth: 4,
            max_records: Some(records),
            ..Default::default()
        },
        |b| {
            n += b.encodings.len();
            true
        },
    );
    assert_eq!(n as u64, records);
    let snap = stats.snapshot();
    assert!(
        snap.buffers_recycled > records / 2,
        "recycle loop barely ran: {snap:?}"
    );
}
