//! Integration tests over the full training pipeline (coordinator +
//! trainer + validation), RustSgd backend. PJRT-backed tests live in
//! integration_runtime.rs (they need `make artifacts`).

use shdc::coordinator::{CatCfg, EncoderCfg, NumCfg};
use shdc::data::synthetic::SyntheticConfig;
use shdc::encoding::BundleMethod;
use shdc::pipeline::{train, TrainCfg};

fn base_cfg(seed: u64) -> (TrainCfg, SyntheticConfig) {
    let data = SyntheticConfig {
        alphabet_size: 20_000,
        noise: 0.3,
        ..SyntheticConfig::sampled(seed)
    };
    (TrainCfg::quick_test(seed), data)
}

#[test]
fn auc_improves_with_dimension() {
    // The Fig. 8B shape: more encoding dimension, better AUC (until
    // saturation). Check the low end of the curve where it must be steep.
    let (mut cfg, data) = base_cfg(21);
    cfg.encoder.num = NumCfg::None;
    let mut aucs = Vec::new();
    for d in [64usize, 2048] {
        cfg.encoder.cat = CatCfg::Bloom { d, k: 4 };
        let rep = train(&cfg, &data).unwrap();
        aucs.push(rep.median_test_auc());
    }
    assert!(
        aucs[1] > aucs[0] + 0.02,
        "AUC must improve d=64 -> d=2048: {aucs:?}"
    );
}

#[test]
fn sparse_overfits_less_than_dense_at_large_d() {
    // Fig. 7B's direction: train-val gap for dense-hash >= bloom at
    // equal (large) d — sparse updates touch only ~ks/d of parameters.
    // Uses the fig7b report's workload shape, which shows the effect
    // robustly (gap ~0.09 dense vs ~0.02 sparse at d=8192).
    let (mut cfg, mut data) = base_cfg(22);
    data.alphabet_size = 200_000;
    data.noise = 0.6;
    cfg.train_records = 60_000;
    cfg.validate_every = 7_500;
    cfg.val_records = 4_000;
    cfg.test_records = 2_000;
    cfg.batch_size = 256; // the sweep batch: lr below is tuned for it
    cfg.encoder.num = NumCfg::None;
    cfg.encoder.cat = CatCfg::Bloom { d: 8192, k: 4 };
    cfg.lr = 0.5;
    let sparse = train(&cfg, &data).unwrap();
    cfg.encoder.cat = CatCfg::DenseHash { d: 8192, literal: false };
    // Dense-hash coordinates have O(s) magnitude; use a correspondingly
    // smaller step (the paper tunes per configuration on validation).
    cfg.lr = 0.005;
    let dense = train(&cfg, &data).unwrap();
    assert!(
        dense.train_val_gap > sparse.train_val_gap - 0.01,
        "dense gap {:.4} should exceed sparse gap {:.4}",
        dense.train_val_gap,
        sparse.train_val_gap
    );
}

#[test]
fn deterministic_given_seed() {
    let (cfg, data) = base_cfg(23);
    let a = train(&cfg, &data).unwrap();
    let b = train(&cfg, &data).unwrap();
    assert_eq!(a.test_auc_chunks, b.test_auc_chunks);
    assert_eq!(a.records_trained, b.records_trained);
    assert!((a.final_val_loss - b.final_val_loss).abs() < 1e-12);
}

#[test]
fn worker_count_does_not_change_results() {
    let (mut cfg, data) = base_cfg(24);
    cfg.n_workers = 1;
    let a = train(&cfg, &data).unwrap();
    cfg.n_workers = 6;
    let b = train(&cfg, &data).unwrap();
    assert_eq!(a.test_auc_chunks, b.test_auc_chunks, "parallelism must not change math");
}

#[test]
fn imbalanced_stream_trains_and_reports_sane_auc() {
    // The Sec. 7.5 regime: 96% negatives.
    let (mut cfg, mut data) = base_cfg(25);
    data.positive_rate = 0.04;
    cfg.train_records = 30_000;
    let rep = train(&cfg, &data).unwrap();
    assert!(rep.median_test_auc() > 0.6, "AUC {}", rep.median_test_auc());
    assert!(rep.final_val_loss < 0.4, "val loss {}", rep.final_val_loss);
}

#[test]
fn bundling_methods_all_train_comparably() {
    // Fig. 10: the three bundling methods land within a few AUC points.
    let (mut cfg, data) = base_cfg(26);
    let mut aucs = Vec::new();
    for bundle in [BundleMethod::Concat, BundleMethod::Sum, BundleMethod::ThresholdedSum] {
        cfg.encoder = EncoderCfg {
            cat: CatCfg::Bloom { d: 1024, k: 4 },
            num: NumCfg::SparseTopK { d: 1024, k: 64 },
            bundle,
            n_numeric: 13,
            seed: 26,
        };
        let rep = train(&cfg, &data).unwrap();
        aucs.push(rep.median_test_auc());
    }
    let max = aucs.iter().cloned().fold(f64::MIN, f64::max);
    let min = aucs.iter().cloned().fold(f64::MAX, f64::min);
    assert!(max - min < 0.08, "bundling spread too large: {aucs:?}");
    assert!(min > 0.7, "all bundling methods should learn: {aucs:?}");
}

#[test]
fn report_throughput_counters_populated() {
    let (cfg, data) = base_cfg(27);
    let rep = train(&cfg, &data).unwrap();
    assert!(rep.stats.encode_throughput() > 0.0);
    assert!(rep.stats.train_throughput() > 0.0);
    assert!(rep.stats.records_encoded >= rep.records_trained);
    assert!(rep.wall.as_nanos() > 0);
}
