//! Differential suite for the encode kernel layer
//! (`shdc::encoding::kernels`): the **active** backend (scalar by
//! default, `std::simd` under `--features simd`) must be **bit-identical**
//! to the always-compiled `scalar` backend for every kernel, across
//! randomized shapes, buffer alignments, non-multiple-of-lane-width
//! tails, empty inputs, and IEEE edge values (±0, NaN, ±inf,
//! subnormals).
//!
//! Run it in both configurations; the test output must be identical:
//!
//! ```text
//! cargo test -q --test kernel_equivalence
//! cargo +nightly test -q --test kernel_equivalence --features simd
//! ```
//!
//! With the feature off the scalar-vs-active comparison is trivially
//! true, so every suite *also* checks against an independent inline
//! reference implementation — the tests have teeth in both builds, and
//! the encoder-level suites prove the kernel rewiring preserved each
//! encoder's map exactly.

use shdc::encoding::kernels::{self, scalar, LANES};
use shdc::encoding::{BloomEncoder, DenseHashEncoder, DenseHashMode, EncodeScratch, Encoding, Sjlt};
use shdc::hash::murmur3_u64;
use shdc::util::rng::Rng;

/// Lengths covering empty, sub-lane, exact-lane, lane±1 (LANES = 8),
/// bitset word boundaries (63/64/65) and larger non-round sizes.
const SIZES: &[usize] = &[0, 1, 2, 3, 7, 8, 9, 15, 16, 17, 31, 32, 33, 63, 64, 65, 100, 257, 1003];

/// Offsets into a parent allocation: SIMD loads must be correct at any
/// alignment, and results identical regardless of where the slice starts.
const OFFSETS: &[usize] = &[0, 1, 3, 5];

fn assert_bits_eq(a: &[f32], b: &[f32], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{ctx}: coord {i} differs bitwise: {x:?} vs {y:?}"
        );
    }
}

fn random_f32s(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32()).collect()
}

/// A buffer mixing normal draws with IEEE edge values.
fn edgy_f32s(rng: &mut Rng, n: usize) -> Vec<f32> {
    const SPECIALS: &[f32] = &[
        0.0,
        -0.0,
        f32::NAN,
        f32::INFINITY,
        f32::NEG_INFINITY,
        f32::MIN_POSITIVE,
        -f32::MIN_POSITIVE,
        1e-45,  // smallest positive subnormal
        -1e-45,
    ];
    (0..n)
        .map(|i| {
            if rng.bernoulli(0.3) {
                SPECIALS[i % SPECIALS.len()]
            } else {
                rng.normal_f32()
            }
        })
        .collect()
}

#[test]
fn axpy_active_matches_scalar_bitwise() {
    let mut rng = Rng::new(0xa0);
    for &len in SIZES {
        for &off in OFFSETS {
            let total = off + len;
            let col = random_f32s(&mut rng, total);
            let base = random_f32s(&mut rng, total);
            let xv = rng.normal_f32();
            let mut za = base.clone();
            let mut zb = base.clone();
            scalar::axpy(&mut za[off..], &col[off..], xv);
            kernels::axpy(&mut zb[off..], &col[off..], xv);
            assert_bits_eq(&za, &zb, &format!("axpy len={len} off={off}"));
            // Reference: one mul + one add per element, element order.
            let mut want = base.clone();
            for i in off..total {
                want[i] += col[i] * xv;
            }
            assert_bits_eq(&want, &zb, &format!("axpy-vs-ref len={len} off={off}"));
        }
    }
}

#[test]
fn sign_quantize_active_matches_scalar_bitwise_including_edge_values() {
    let mut rng = Rng::new(0xa1);
    for &len in SIZES {
        for &off in OFFSETS {
            let base = edgy_f32s(&mut rng, off + len);
            let mut za = base.clone();
            let mut zb = base.clone();
            scalar::sign_quantize(&mut za[off..]);
            kernels::sign_quantize(&mut zb[off..]);
            assert_bits_eq(&za, &zb, &format!("sign_quantize len={len} off={off}"));
            // Reference: sign(0) := +1 (both zeros), NaN compares false -> -1.
            for (i, (&src, &got)) in base[off..].iter().zip(&zb[off..]).enumerate() {
                let want = if src >= 0.0 { 1.0f32 } else { -1.0 };
                assert_eq!(want.to_bits(), got.to_bits(), "coord {i} of {src:?}");
            }
        }
    }
}

#[test]
fn scatter_signed_active_matches_scalar_bitwise_under_collisions() {
    let mut rng = Rng::new(0xa2);
    for &n in SIZES {
        for &off in OFFSETS {
            // Small output range forces bucket collisions, so the
            // accumulate *order* is exercised, not just the values.
            let out_len = 1 + rng.below_usize(1 + 2 * n.max(1));
            let x = random_f32s(&mut rng, off + n);
            let eta: Vec<u32> =
                (0..off + n).map(|_| rng.below(out_len as u64) as u32).collect();
            let sigma: Vec<i8> = (0..off + n).map(|_| rng.sign() as i8).collect();
            let base = random_f32s(&mut rng, out_len);
            let mut oa = base.clone();
            let mut ob = base.clone();
            scalar::scatter_signed(&x[off..], &eta[off..], &sigma[off..], &mut oa);
            kernels::scatter_signed(&x[off..], &eta[off..], &sigma[off..], &mut ob);
            assert_bits_eq(&oa, &ob, &format!("scatter n={n} off={off} out={out_len}"));
            // Reference: ascending-j signed scatter-adds.
            let mut want = base.clone();
            for j in off..off + n {
                let v = if sigma[j] >= 0 { x[j] } else { -x[j] };
                want[eta[j] as usize] += v;
            }
            assert_bits_eq(&want, &ob, &format!("scatter-vs-ref n={n} off={off}"));
        }
    }
}

#[test]
fn unpack_sign_bits_active_matches_scalar_bitwise() {
    let mut rng = Rng::new(0xa3);
    for len in 0..=32usize {
        for _ in 0..4 {
            let word = rng.next_u32();
            let base = random_f32s(&mut rng, len);
            let mut aa = base.clone();
            let mut ab = base.clone();
            scalar::unpack_sign_bits_accumulate(word, &mut aa);
            kernels::unpack_sign_bits_accumulate(word, &mut ab);
            assert_bits_eq(&aa, &ab, &format!("unpack len={len} word={word:#x}"));
            // Reference: bit i of word -> ±1 added to acc[i].
            let mut want = base.clone();
            for (i, w) in want.iter_mut().enumerate() {
                *w += if (word >> i) & 1 == 0 { 1.0 } else { -1.0 };
            }
            assert_bits_eq(&want, &ab, &format!("unpack-vs-ref len={len}"));
        }
    }
}

#[test]
fn bitset_sweep_active_matches_scalar_and_sort_dedup() {
    let mut rng = Rng::new(0xa4);
    for case in 0..200u32 {
        let d = 1 + rng.below_usize(6000);
        let n = rng.below_usize(300);
        let staged: Vec<u32> = (0..n).map(|_| rng.below(d as u64) as u32).collect();
        let words = d.div_ceil(64);
        let mut bs_a = vec![0u64; words];
        let mut bs_b = vec![0u64; words];
        let mut out_a: Vec<u32> = Vec::new();
        let mut out_b: Vec<u32> = Vec::new();
        if !staged.is_empty() {
            let (lo_a, hi_a) = kernels::bitset_mark(&mut bs_a, &staged);
            let (lo_b, hi_b) = kernels::bitset_mark(&mut bs_b, &staged);
            assert_eq!((lo_a, hi_a), (lo_b, hi_b), "case {case}: mark span");
            scalar::bitset_sweep(&mut bs_a, lo_a, hi_a, &mut out_a);
            kernels::bitset_sweep(&mut bs_b, lo_b, hi_b, &mut out_b);
        }
        assert_eq!(out_a, out_b, "case {case}: sweep output (d={d} n={n})");
        assert!(bs_a.iter().all(|&w| w == 0), "case {case}: scalar left dirty bits");
        assert!(bs_b.iter().all(|&w| w == 0), "case {case}: active left dirty bits");
        // Reference: the legacy sort+dedup (also a kernel — same module).
        let mut want = staged.clone();
        kernels::sort_dedup(&mut want);
        assert_eq!(want, out_b, "case {case}: sweep != sort+dedup");
    }
}

#[test]
fn dot_f32_active_matches_scalar_bitwise_and_striped_reference() {
    let mut rng = Rng::new(0xa5);
    for &len in SIZES {
        for &off in OFFSETS {
            let a = edgy_f32s(&mut rng, off + len);
            let b = random_f32s(&mut rng, off + len);
            let xs = scalar::dot_f32(&a[off..], &b[off..]);
            let xa = kernels::dot_f32(&a[off..], &b[off..]);
            assert_eq!(
                xs.to_bits(),
                xa.to_bits(),
                "dot_f32 len={len} off={off}: {xs:?} vs {xa:?}"
            );
            // Independent reference implementing the striped contract:
            // LANES partial sums over full chunks, fixed fold tree,
            // sequential tail.
            let (aa, bb) = (&a[off..], &b[off..]);
            let main = len - len % LANES;
            let mut acc = [0.0f32; LANES];
            for i in (0..main).step_by(LANES) {
                for l in 0..LANES {
                    acc[l] += aa[i + l] * bb[i + l];
                }
            }
            let mut tail = 0.0f32;
            for i in main..len {
                tail += aa[i] * bb[i];
            }
            let want = kernels::fold_lanes(acc) + tail;
            assert_eq!(want.to_bits(), xa.to_bits(), "dot_f32-vs-ref len={len} off={off}");
        }
    }
}

#[test]
fn dot_i8_active_matches_scalar_and_naive_exactly() {
    let mut rng = Rng::new(0xa6);
    for &len in SIZES {
        for &off in OFFSETS {
            let a: Vec<i8> = (0..off + len).map(|_| (rng.next_u32() as i8)).collect();
            let b: Vec<i8> = (0..off + len)
                .map(|i| if i % 7 == 0 { i8::MIN } else { rng.next_u32() as i8 })
                .collect();
            let xs = scalar::dot_i8(&a[off..], &b[off..]);
            let xa = kernels::dot_i8(&a[off..], &b[off..]);
            // Naive independent i64 sum — integer, so all three exact.
            let want: i64 =
                a[off..].iter().zip(&b[off..]).map(|(&x, &y)| x as i64 * y as i64).sum();
            assert_eq!(xs, want, "scalar dot_i8 len={len} off={off}");
            assert_eq!(xa, want, "active dot_i8 len={len} off={off}");
        }
    }
}

/// Extreme-magnitude stress for the widening int8 dot, at lengths
/// straddling the 16-element SIMD block (0, 1, 15, 16, 17, 64, 130):
/// constant worst-case patterns make every block hit the largest
/// possible intermediate values *deterministically*, where random draws
/// would almost never align them. In particular, adjacent
/// `(−128)·(−128)` pairs sum to 32768 — one past `i16::MAX` — so a
/// kernel that summed product pairs in i16 lanes would wrap here.
#[test]
fn dot_i8_extreme_magnitudes_exact_at_block_boundaries() {
    const LENS: &[usize] = &[0, 1, 15, 16, 17, 64, 130];
    // (a-fill, b-fill) worst cases: saturated quantizer output (±127)
    // and the full-range i8 extremes (−128).
    const PATTERNS: &[(i8, i8)] = &[
        (127, 127),
        (127, -127),
        (-127, -127),
        (i8::MIN, i8::MIN),
        (i8::MIN, 127),
        (127, i8::MIN),
    ];
    for &len in LENS {
        for &(fa, fb) in PATTERNS {
            let a = vec![fa; len];
            let b = vec![fb; len];
            let want = fa as i64 * fb as i64 * len as i64;
            assert_eq!(scalar::dot_i8(&a, &b), want, "scalar {fa}·{fb} len={len}");
            assert_eq!(kernels::dot_i8(&a, &b), want, "active {fa}·{fb} len={len}");
        }
        // Alternating-sign extremes: lane cancellation inside a block.
        let a: Vec<i8> = (0..len).map(|i| if i % 2 == 0 { 127 } else { -128 }).collect();
        let b: Vec<i8> = (0..len).map(|i| if i % 3 == 0 { -128 } else { 127 }).collect();
        let want: i64 = a.iter().zip(&b).map(|(&x, &y)| x as i64 * y as i64).sum();
        assert_eq!(scalar::dot_i8(&a, &b), want, "scalar alternating len={len}");
        assert_eq!(kernels::dot_i8(&a, &b), want, "active alternating len={len}");
    }
}

#[test]
fn packed_popcounts_active_match_scalar_and_naive() {
    // Word counts cover empty, sub-block (POP_BLOCK = 4), block±1 and
    // larger; all-zero words, all-ones words and random words mixed.
    let mut rng = Rng::new(0xa7);
    for &words in &[0usize, 1, 2, 3, 4, 5, 7, 8, 9, 31, 64, 157] {
        for case in 0..4 {
            let gen = |rng: &mut Rng| -> Vec<u64> {
                (0..words)
                    .map(|i| match (i + case) % 4 {
                        0 => 0,                // all-zero word
                        1 => u64::MAX,         // all-ones word
                        _ => rng.next_u64(),
                    })
                    .collect()
            };
            let a = gen(&mut rng);
            let b = gen(&mut rng);
            let want_x: u64 =
                a.iter().zip(&b).map(|(&x, &y)| (x ^ y).count_ones() as u64).sum();
            let want_a: u64 =
                a.iter().zip(&b).map(|(&x, &y)| (x & y).count_ones() as u64).sum();
            assert_eq!(scalar::hamming_packed(&a, &b), want_x, "scalar ^ words={words}");
            assert_eq!(kernels::hamming_packed(&a, &b), want_x, "active ^ words={words}");
            assert_eq!(scalar::and_popcount(&a, &b), want_a, "scalar & words={words}");
            assert_eq!(kernels::and_popcount(&a, &b), want_a, "active & words={words}");
            // Self-distance is zero / self-overlap is the popcount.
            assert_eq!(kernels::hamming_packed(&a, &a), 0);
            let pop: u64 = a.iter().map(|w| w.count_ones() as u64).sum();
            assert_eq!(kernels::and_popcount(&a, &a), pop);
        }
    }
}

// ---------------------------------------------------------------------------
// Encoder-level wiring: the rewired encoders must still compute exactly
// the map the naive (pre-kernel-layer) loops computed.
// ---------------------------------------------------------------------------

#[test]
fn sjlt_encode_matches_naive_chunk_loop_bitwise() {
    let mut rng = Rng::new(0xb0);
    for case in 0..30u32 {
        let k = 1 + rng.below_usize(4);
        let dk = 1 + rng.below_usize(200);
        let n = rng.below_usize(40);
        let d = dk * k;
        let s = Sjlt::new(d, n, k, &mut rng);
        let x = random_f32s(&mut rng, n);
        let got = match s.encode_record(&x) {
            Encoding::Dense(v) => v,
            _ => panic!(),
        };
        // Naive two-level reference via the public table accessors.
        let mut want = vec![0.0f32; d];
        for c in 0..k {
            for j in 0..n {
                let v = if s.sigma_at(c, j) >= 0.0 { x[j] } else { -x[j] };
                want[c * dk + s.eta_at(c, j) as usize] += v;
            }
        }
        assert_bits_eq(&want, &got, &format!("sjlt case {case} d={d} n={n} k={k}"));
    }
}

#[test]
fn dense_hash_packed_alloc_and_scratch_paths_agree_at_word_tails() {
    // Dimensions straddling the 32-bit word boundary exercise the
    // unpack kernel's tail handling through the real encoder; the
    // allocating and scratch paths must agree exactly.
    let mut rng = Rng::new(0xb1);
    for &d in &[1usize, 31, 32, 33, 64, 257, 1000] {
        let enc = DenseHashEncoder::new(d, DenseHashMode::Packed, &mut rng);
        let mut scratch = EncodeScratch::new();
        for sym in 0..20u64 {
            let a = enc.encode_symbol(sym);
            let b = enc.encode_set_with(&[sym], &mut scratch);
            assert_eq!(a, b, "d={d} sym={sym}");
            if let Encoding::Dense(v) = &a {
                assert_eq!(v.len(), d);
                assert!(v.iter().all(|&z| z == 1.0 || z == -1.0), "d={d} sym={sym}");
            } else {
                panic!();
            }
            scratch.recycle(b);
        }
    }
}

#[test]
fn unpack_kernel_agrees_with_murmur_bit_convention() {
    // The packed dense-hash contract: bit j of murmur3_u64(sym, seed)
    // equal to 0 encodes +1. Drive the kernel with real hash words and
    // check the sign convention against direct bit tests.
    let mut rng = Rng::new(0xb2);
    for _ in 0..50 {
        let seed = rng.next_u32();
        let sym = rng.next_u64();
        let word = murmur3_u64(sym, seed);
        let mut acc = vec![0.0f32; 32];
        kernels::unpack_sign_bits_accumulate(word, &mut acc);
        for (j, &a) in acc.iter().enumerate() {
            let want = if (word >> j) & 1 == 0 { 1.0 } else { -1.0 };
            assert_eq!(a, want, "bit {j} of {word:#010x}");
        }
    }
}

#[test]
fn bloom_dedup_paths_agree_across_random_dims() {
    // Legacy sort+dedup (kernels::sort_dedup via sparse_from_indices)
    // vs scratch bitset mark/sweep (kernels::bitset_*): identical codes
    // at every dimension, including tiny d with heavy self-collisions.
    let mut rng = Rng::new(0xb3);
    let mut scratch = EncodeScratch::new();
    for case in 0..60u32 {
        let d = 8 + rng.below_usize(8192);
        let k = 1 + rng.below_usize(8);
        let enc = BloomEncoder::new(d, k, &mut rng);
        let s = rng.below_usize(50);
        let set: Vec<u64> = (0..s).map(|_| rng.below(1 << 40)).collect();
        let want = enc.encode_set(&set);
        let got = enc.encode_set_with(&set, &mut scratch);
        assert_eq!(got, want, "case {case} d={d} k={k} s={s}");
        scratch.recycle(got);
    }
}

#[test]
fn backend_reports_feature_state() {
    assert_eq!(kernels::SIMD_ENABLED, cfg!(feature = "simd"));
    assert_eq!(LANES, 8);
}
