"""Kernel-vs-oracle correctness: the CORE Layer-1 signal.

Every Pallas kernel must match its pure-jnp reference in ``kernels.ref``
bit-for-bit up to float tolerance, across a hypothesis sweep of shapes,
block sizes, seeds and value ranges.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import logistic, projection, ref, sjlt

RTOL = 1e-5
ATOL = 1e-5


def _rng(seed):
    return np.random.default_rng(seed)


def _close(a, b):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=RTOL, atol=ATOL)


# --------------------------------------------------------------------------
# projection kernel
# --------------------------------------------------------------------------


class TestProjection:
    @pytest.mark.parametrize("mode", ["none", "sign", "threshold"])
    def test_matches_ref_basic(self, mode):
        rng = _rng(1)
        x = jnp.array(rng.normal(size=(16, 13)), jnp.float32)
        phi = jnp.array(rng.normal(size=(128, 13)), jnp.float32)
        t = jnp.array([0.7], jnp.float32)
        got = projection.project(x, phi, t, mode=mode)
        want = ref.project(x, phi, mode=mode, threshold=0.7)
        _close(got, want)

    @settings(max_examples=40, deadline=None)
    @given(
        b=st.integers(1, 33),
        n=st.integers(1, 40),
        dblocks=st.integers(1, 6),
        bd=st.sampled_from([1, 2, 8, 32, 128]),
        mode=st.sampled_from(["none", "sign", "threshold"]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_sweep(self, b, n, dblocks, bd, mode, seed):
        d = dblocks * bd
        rng = _rng(seed)
        x = jnp.array(rng.normal(size=(b, n)) * 3, jnp.float32)
        phi = jnp.array(rng.normal(size=(d, n)), jnp.float32)
        t = jnp.array([abs(rng.normal())], jnp.float32)
        got = projection.project(x, phi, t, mode=mode, block_d=bd)
        want = ref.project(x, phi, mode=mode, threshold=float(t[0]))
        _close(got, want)

    def test_sign_of_zero_is_plus_one(self):
        # Paper: q(u) = +1 if u >= 0 — exact-zero projections must be +1.
        x = jnp.zeros((2, 4), jnp.float32)
        phi = jnp.ones((8, 4), jnp.float32)
        out = projection.project(x, phi, jnp.zeros((1,), jnp.float32), mode="sign")
        assert np.all(np.asarray(out) == 1.0)

    def test_threshold_output_is_binary(self):
        rng = _rng(3)
        x = jnp.array(rng.normal(size=(9, 13)), jnp.float32)
        phi = jnp.array(rng.normal(size=(64, 13)), jnp.float32)
        out = np.asarray(
            projection.project(x, phi, jnp.array([0.5], jnp.float32), mode="threshold")
        )
        assert set(np.unique(out)) <= {0.0, 1.0}

    def test_pick_block_d_divides(self):
        for d in [1, 7, 128, 500, 512, 2048, 10000, 9999]:
            bd = projection.pick_block_d(d)
            assert d % bd == 0 and 1 <= bd <= max(d, 1)

    def test_block_size_invariance(self):
        rng = _rng(4)
        x = jnp.array(rng.normal(size=(8, 13)), jnp.float32)
        phi = jnp.array(rng.normal(size=(96, 13)), jnp.float32)
        t = jnp.zeros((1,), jnp.float32)
        full = projection.project(x, phi, t, mode="none", block_d=96)
        for bd in [1, 2, 3, 4, 8, 16, 32, 48]:
            _close(projection.project(x, phi, t, mode="none", block_d=bd), full)


# --------------------------------------------------------------------------
# SJLT kernel
# --------------------------------------------------------------------------


class TestSjlt:
    def _case(self, b, n, k, dk, seed):
        rng = _rng(seed)
        x = jnp.array(rng.normal(size=(b, n)), jnp.float32)
        eta = jnp.array(rng.integers(0, dk, size=(k, n)), jnp.int32)
        sigma = jnp.array(rng.choice([-1.0, 1.0], size=(k, n)), jnp.float32)
        return x, eta, sigma, k * dk

    def test_matches_ref_basic(self):
        x, eta, sigma, d = self._case(16, 13, 4, 32, 7)
        _close(sjlt.sjlt(x, eta, sigma, d=d), ref.sjlt(x, eta, sigma, d))

    @settings(max_examples=40, deadline=None)
    @given(
        b=st.integers(1, 20),
        n=st.integers(1, 30),
        k=st.integers(1, 6),
        dk=st.integers(1, 64),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_sweep(self, b, n, k, dk, seed):
        x, eta, sigma, d = self._case(b, n, k, dk, seed)
        _close(sjlt.sjlt(x, eta, sigma, d=d), ref.sjlt(x, eta, sigma, d))

    def test_norm_preservation_in_expectation(self):
        # JL property: E[||phi(x)||^2] = k * ||x||^2 (each chunk preserves
        # the norm in expectation). Check the empirical mean over draws.
        rng = _rng(11)
        n, k, dk, trials = 20, 4, 64, 200
        x = rng.normal(size=(1, n)).astype(np.float32)
        target = k * float((x**2).sum())
        acc = 0.0
        for i in range(trials):
            eta = jnp.array(rng.integers(0, dk, size=(k, n)), jnp.int32)
            sigma = jnp.array(rng.choice([-1.0, 1.0], size=(k, n)), jnp.float32)
            e = np.asarray(sjlt.sjlt(jnp.array(x), eta, sigma, d=k * dk))
            acc += float((e**2).sum())
        assert abs(acc / trials - target) / target < 0.15

    def test_single_coordinate_routing(self):
        # x = e_j must land sign sigma_c(j) at bucket eta_c(j) of chunk c.
        n, k, dk = 5, 3, 8
        x = jnp.zeros((1, n), jnp.float32).at[0, 2].set(1.0)
        eta = jnp.array([[0, 1, 5, 3, 4]] * k, jnp.int32)
        sigma = jnp.array([[1, 1, -1, 1, 1]] * k, jnp.float32)
        out = np.asarray(sjlt.sjlt(x, eta, sigma, d=k * dk)).reshape(k, dk)
        for c in range(k):
            want = np.zeros(dk)
            want[5] = -1.0
            np.testing.assert_array_equal(out[c], want)


# --------------------------------------------------------------------------
# logistic kernels
# --------------------------------------------------------------------------


class TestLogistic:
    def _case(self, b, d, seed):
        rng = _rng(seed)
        theta = jnp.array(rng.normal(size=(d,)) * 0.1, jnp.float32)
        phi = jnp.array(rng.normal(size=(b, d)), jnp.float32)
        y = jnp.array(rng.integers(0, 2, size=(b,)), jnp.float32)
        return theta, phi, y

    def test_matvec_matches_ref(self):
        theta, phi, _ = self._case(16, 96, 21)
        _close(logistic.matvec(phi, theta), ref.logistic_forward(theta, phi))

    @settings(max_examples=30, deadline=None)
    @given(
        b=st.integers(1, 24),
        dblocks=st.integers(1, 5),
        bd=st.sampled_from([1, 3, 16, 64]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matvec_sweep(self, b, dblocks, bd, seed):
        theta, phi, _ = self._case(b, dblocks * bd, seed)
        _close(
            logistic.matvec(phi, theta, block_d=bd),
            ref.logistic_forward(theta, phi),
        )

    @settings(max_examples=30, deadline=None)
    @given(
        b=st.integers(1, 24),
        dblocks=st.integers(1, 5),
        bd=st.sampled_from([1, 3, 16, 64]),
        lr=st.floats(1e-4, 1.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_train_step_sweep(self, b, dblocks, bd, lr, seed):
        theta, phi, y = self._case(b, dblocks * bd, seed)
        t_new, loss = logistic.train_step(
            theta, phi, y, jnp.array([lr], jnp.float32), block_d=bd
        )
        t_ref, l_ref = ref.logistic_update(theta, phi, y, lr)
        _close(t_new, t_ref)
        np.testing.assert_allclose(float(loss), float(l_ref), rtol=1e-4, atol=1e-5)

    def test_update_matches_manual_gradient(self):
        theta, phi, y = self._case(8, 32, 33)
        lr = jnp.array([0.3], jnp.float32)
        z = np.asarray(phi) @ np.asarray(theta)
        err = jnp.array(np.asarray(y) - 1 / (1 + np.exp(-z)), jnp.float32)
        got = logistic.update(theta, phi, err, lr)
        want = np.asarray(theta) + 0.3 * (np.asarray(phi).T @ np.asarray(err)) / 8
        _close(got, want)

    def test_loss_decreases_over_steps(self):
        # SGD on a linearly-separable toy problem must reduce the NLL.
        rng = _rng(5)
        d, b = 64, 32
        w_true = rng.normal(size=(d,))
        theta = jnp.zeros((d,), jnp.float32)
        lr = jnp.array([0.5], jnp.float32)
        losses = []
        for i in range(30):
            phi = rng.normal(size=(b, d)).astype(np.float32)
            y = (phi @ w_true > 0).astype(np.float32)
            theta, loss = logistic.train_step(theta, jnp.array(phi), jnp.array(y), lr)
            losses.append(float(loss))
        assert np.mean(losses[-5:]) < 0.8 * np.mean(losses[:5])
