"""Layer-2 model tests: composition, gradients, and training behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def _rng(seed=0):
    return np.random.default_rng(seed)


class TestEncoders:
    def test_encode_project_sign_shape_and_values(self):
        rng = _rng(1)
        x = jnp.array(rng.normal(size=(8, 13)), jnp.float32)
        phi = jnp.array(rng.normal(size=(64, 13)), jnp.float32)
        (out,) = model.encode_project_sign(x, phi, jnp.zeros((1,), jnp.float32))
        assert out.shape == (8, 64)
        assert set(np.unique(np.asarray(out))) <= {-1.0, 1.0}

    def test_encode_project_threshold_sparsity_tunable(self):
        # Larger threshold => sparser code (Sec. 5.3's knob).
        rng = _rng(2)
        x = jnp.array(rng.normal(size=(32, 13)), jnp.float32)
        phi = jnp.array(rng.normal(size=(256, 13)) / np.sqrt(13), jnp.float32)
        dens = []
        for t in [0.5, 1.5, 2.5]:
            (out,) = model.encode_project_threshold(
                x, phi, jnp.array([t], jnp.float32)
            )
            dens.append(float(np.asarray(out).mean()))
        assert dens[0] > dens[1] > dens[2]

    def test_encode_sjlt_shape(self):
        rng = _rng(3)
        x = jnp.array(rng.normal(size=(8, 13)), jnp.float32)
        eta = jnp.array(rng.integers(0, 16, size=(4, 13)), jnp.int32)
        sig = jnp.array(rng.choice([-1.0, 1.0], size=(4, 13)), jnp.float32)
        (out,) = model.make_encode_sjlt(64)(x, eta, sig)
        assert out.shape == (8, 64)


class TestFusedPath:
    def test_fused_equals_manual_composition(self):
        rng = _rng(4)
        b, n, dn, dc = 8, 13, 64, 96
        theta = jnp.array(rng.normal(size=(dn + dc,)) * 0.1, jnp.float32)
        x = jnp.array(rng.normal(size=(b, n)), jnp.float32)
        phim = jnp.array(rng.normal(size=(dn, n)), jnp.float32)
        phic = jnp.array(rng.integers(0, 2, size=(b, dc)), jnp.float32)
        y = jnp.array(rng.integers(0, 2, size=(b,)), jnp.float32)
        lr = jnp.array([0.2], jnp.float32)

        t_fused, l_fused = model.fused_train_sign_concat(theta, x, phim, phic, y, lr)

        phin = ref.project(x, phim, mode="sign")
        phi = jnp.concatenate([phin, phic], axis=1)
        t_ref, l_ref = ref.logistic_update(theta, phi, y, 0.2)
        np.testing.assert_allclose(np.asarray(t_fused), np.asarray(t_ref), rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(float(l_fused), float(l_ref), rtol=1e-5)

    def test_fused_predict_in_unit_interval(self):
        rng = _rng(5)
        b, n, dn, dc = 8, 13, 64, 96
        theta = jnp.array(rng.normal(size=(dn + dc,)), jnp.float32)
        x = jnp.array(rng.normal(size=(b, n)), jnp.float32)
        phim = jnp.array(rng.normal(size=(dn, n)), jnp.float32)
        phic = jnp.array(rng.integers(0, 2, size=(b, dc)), jnp.float32)
        (p,) = model.fused_predict_sign_concat(theta, x, phim, phic)
        p = np.asarray(p)
        assert p.shape == (b,) and np.all(p > 0) and np.all(p < 1)


class TestTrainEval:
    def test_loss_eval_matches_ref(self):
        rng = _rng(6)
        theta = jnp.array(rng.normal(size=(64,)) * 0.1, jnp.float32)
        phi = jnp.array(rng.normal(size=(16, 64)), jnp.float32)
        y = jnp.array(rng.integers(0, 2, size=(16,)), jnp.float32)
        (got,) = model.loss_eval(theta, phi, y)
        want = ref.logistic_loss(theta, phi, y)
        np.testing.assert_allclose(float(got), float(want), rtol=1e-5)

    def test_predict_sigmoid_of_scores(self):
        rng = _rng(7)
        theta = jnp.array(rng.normal(size=(64,)), jnp.float32)
        phi = jnp.array(rng.normal(size=(16, 64)), jnp.float32)
        (p,) = model.predict(theta, phi)
        z = np.asarray(phi) @ np.asarray(theta)
        np.testing.assert_allclose(np.asarray(p), 1 / (1 + np.exp(-z)), rtol=1e-5)


class TestMlp:
    def test_init_shapes(self):
        params = model.mlp_init(13, 96)
        assert params[0].shape == (13, 512)
        assert params[-1].shape == (16 + 96,)
        assert len(params) == 2 * len(model.MLP_WIDTHS) + 1

    def test_grad_matches_finite_difference(self):
        # Spot-check the AOT'd analytic gradient against central differences
        # on a few coordinates of W1 and theta.
        rng = _rng(8)
        n, dc, b = 5, 7, 6
        params = tuple(
            jnp.array(rng.normal(size=p.shape) * 0.3, jnp.float32)
            for p in model.mlp_init(n, dc, seed=1)
        )
        x = jnp.array(rng.normal(size=(b, n)), jnp.float32)
        phic = jnp.array(rng.integers(0, 2, size=(b, dc)), jnp.float32)
        y = jnp.array(rng.integers(0, 2, size=(b,)), jnp.float32)

        loss_fn = lambda ps: model._mlp_loss(ps, x, phic, y)
        grads = jax.grad(loss_fn)(params)

        eps = 1e-3
        for pi, coords in [(0, [(0, 0), (2, 3)]), (len(params) - 1, [(0,), (3,)])]:
            for c in coords:
                up = [jnp.array(p) for p in params]
                dn = [jnp.array(p) for p in params]
                up[pi] = up[pi].at[c].add(eps)
                dn[pi] = dn[pi].at[c].add(-eps)
                fd = (loss_fn(tuple(up)) - loss_fn(tuple(dn))) / (2 * eps)
                np.testing.assert_allclose(
                    float(grads[pi][c]), float(fd), rtol=5e-2, atol=5e-4
                )

    def test_train_step_reduces_loss(self):
        rng = _rng(9)
        n, dc, b = 8, 16, 32
        params = model.mlp_init(n, dc, seed=2)
        lr = jnp.array([0.05], jnp.float32)
        w_num = rng.normal(size=(n,))
        losses = []
        for i in range(40):
            x = rng.normal(size=(b, n)).astype(np.float32)
            phic = rng.integers(0, 2, size=(b, dc)).astype(np.float32)
            y = (x @ w_num > 0).astype(np.float32)
            out = model.mlp_train_step(*params, jnp.array(x), jnp.array(phic), jnp.array(y), lr)
            params, loss = out[:-1], out[-1]
            losses.append(float(loss))
        assert np.mean(losses[-5:]) < np.mean(losses[:5])

    def test_predict_range(self):
        rng = _rng(10)
        n, dc, b = 6, 10, 4
        params = model.mlp_init(n, dc, seed=3)
        x = jnp.array(rng.normal(size=(b, n)), jnp.float32)
        phic = jnp.array(rng.integers(0, 2, size=(b, dc)), jnp.float32)
        (p,) = model.mlp_predict(*params, x, phic)
        p = np.asarray(p)
        assert p.shape == (b,) and np.all((p >= 0) & (p <= 1))
