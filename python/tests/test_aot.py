"""AOT lowering tests: HLO text artifacts + manifest integrity."""

import json
import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def lowered(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.lower_all(out, ["small"])
    return out, manifest


class TestAot:
    def test_all_expected_artifacts_present(self, lowered):
        out, manifest = lowered
        expected_fns = {
            "encode_project_sign",
            "encode_project_threshold",
            "encode_project_none",
            "encode_sjlt",
            "train_step",
            "predict",
            "loss_eval",
            "fused_train_sign_concat",
            "fused_predict_sign_concat",
            "mlp_train_step",
            "mlp_predict",
        }
        got_fns = {a["fn"] for a in manifest["artifacts"].values()}
        assert got_fns == expected_fns

    def test_files_exist_and_are_hlo_text(self, lowered):
        out, manifest = lowered
        for name, art in manifest["artifacts"].items():
            path = os.path.join(out, art["file"])
            assert os.path.exists(path), name
            text = open(path).read()
            # HLO text, not a serialized proto: must start with the module
            # header and contain an entry computation. (The 64-bit-id proto
            # issue is exactly why we assert on *text* here.)
            assert text.startswith("HloModule"), name
            assert "ENTRY" in text, name

    def test_manifest_shapes_match_profile(self, lowered):
        out, manifest = lowered
        p = aot.PROFILES["small"]
        ts = manifest["artifacts"]["train_step__small"]
        assert ts["inputs"][0]["shape"] == [p.d_total]
        assert ts["inputs"][1]["shape"] == [p.b, p.d_total]
        assert ts["outputs"][0]["shape"] == [p.d_total]
        fused = manifest["artifacts"]["fused_train_sign_concat__small"]
        assert fused["inputs"][2]["shape"] == [p.d_num, p.n]
        assert fused["inputs"][3]["shape"] == [p.b, p.d_cat]

    def test_manifest_json_round_trips(self, lowered):
        out, _ = lowered
        m = json.load(open(os.path.join(out, "manifest.json")))
        assert m["mlp_widths"] == list(model.MLP_WIDTHS)
        for art in m["artifacts"].values():
            for io in art["inputs"] + art["outputs"]:
                assert io["dtype"] in ("float32", "int32")
                assert all(isinstance(s, int) for s in io["shape"])

    def test_mlp_input_count(self, lowered):
        _, manifest = lowered
        art = manifest["artifacts"]["mlp_train_step__small"]
        # 9 params + x + phic + y + lr
        assert len(art["inputs"]) == 2 * len(model.MLP_WIDTHS) + 1 + 4
        # outputs: 9 updated params + loss
        assert len(art["outputs"]) == 2 * len(model.MLP_WIDTHS) + 1 + 1
