"""Pallas kernel: blocked random-projection encoding (paper Eq. 4 / Sec 5.3).

The paper's FPGA design (Sec. 6.1) partitions the projection matrix Phi
row-wise into p coarse partitions x R rows so that one row-block times the
full input vector retires per cycle. The TPU-shaped analog is a Pallas
grid over row-blocks of Phi: each grid step holds one ``(BLOCK_D, n)``
tile of Phi in VMEM together with the whole ``(B, n)`` input batch (n is
small — 13 numeric features for Criteo — so the batch always fits), and
contracts it on the MXU. BlockSpec plays the role of the FPGA partition
schedule; the HBM->VMEM pipeline replaces the BRAM banking.

The optional nonlinearity q matches the paper:
  * "sign"      — Eq. 4's signed projection, sign(0) := +1.
  * "threshold" — Sec. 5.3's sparsification-by-thresholding (the paper's
                  own FPGA substitution for top-k, which needs a sort).
  * "none"      — raw z, used when composing with SJLT or for debugging.

Run with interpret=True everywhere: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default row-block. On a real TPU this is the VMEM sizing knob: 512
# keeps the Phi tile (512 x n f32) plus the batch well under VMEM budget
# and is a multiple of the 128-lane MXU tile. On the CPU-PJRT artifact
# path (interpret=True), every extra grid step becomes a while-loop
# iteration with dynamic-slice traffic, so `make artifacts` can override
# the block size (SHDC_BLOCK_D=0 means "whole array, one grid step" —
# the §Perf setting for CPU executables).
DEFAULT_BLOCK_D = int(os.environ.get("SHDC_BLOCK_D", "512") or "512")


def effective_block(d: int) -> int:
    """Resolve the block policy: 0 => whole-d single step."""
    if DEFAULT_BLOCK_D <= 0:
        return d
    return pick_block_d(d, DEFAULT_BLOCK_D)


def pick_block_d(d: int, preferred: int = DEFAULT_BLOCK_D) -> int:
    """Largest divisor of d that is <= preferred (falls back to d)."""
    if d <= preferred:
        return d
    for b in range(min(preferred, d), 0, -1):
        if d % b == 0:
            return b
    return d


def _project_kernel(x_ref, phi_ref, t_ref, o_ref, *, mode: str):
    """One grid step: contract the (BLOCK_D, n) Phi tile with the batch."""
    x = x_ref[...]  # (B, n)
    phi = phi_ref[...]  # (BLOCK_D, n)
    # MXU-shaped contraction; accumulate in f32 regardless of input dtype.
    z = jax.lax.dot_general(
        x,
        phi,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (B, BLOCK_D)
    if mode == "sign":
        o_ref[...] = jnp.where(z >= 0, 1.0, -1.0).astype(jnp.float32)
    elif mode == "threshold":
        t = t_ref[0]
        o_ref[...] = (jnp.abs(z) >= t).astype(jnp.float32)
    else:
        o_ref[...] = z


@functools.partial(jax.jit, static_argnames=("mode", "block_d"))
def project(x, phi, threshold, *, mode: str = "sign", block_d: int | None = None):
    """Encode a batch with a row-blocked random projection.

    Args:
      x:         (B, n) float batch.
      phi:       (d, n) projection matrix.
      threshold: (1,) float32 threshold (ignored unless mode="threshold";
                 kept as a live input so one artifact serves all modes).
      mode:      "sign" | "threshold" | "none".
      block_d:   row-block size; must divide d. Default: pick_block_d(d).

    Returns:
      (B, d) float32 encoding.
    """
    b, n = x.shape
    d, n2 = phi.shape
    assert n == n2, f"x has {n} features but phi expects {n2}"
    bd = block_d or effective_block(d)
    assert d % bd == 0, f"block_d={bd} must divide d={d}"
    grid = (d // bd,)
    return pl.pallas_call(
        functools.partial(_project_kernel, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, n), lambda i: (0, 0)),  # whole batch, every step
            pl.BlockSpec((bd, n), lambda i: (i, 0)),  # i-th row-block of Phi
            pl.BlockSpec((1,), lambda i: (0,)),  # threshold scalar
        ],
        out_specs=pl.BlockSpec((b, bd), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=True,
    )(x, phi, threshold)
