"""Pallas kernels: fused logistic-regression SGD step (paper Sec. 7.1).

The paper trains a logistic regression over the HD encoding with
mini-batch SGD; its FPGA pipeline (Fig. 1c, Table 2) splits the update
into a score pass ``theta . phi(x)`` and a gradient pass
``(y - sigma(theta . phi)) phi``, both partitioned over the embedding
dimension. We mirror that structure with two D-blocked Pallas kernels:

  * ``matvec``  — z = phi @ theta, grid over D blocks, accumulating the
                  (B,) partial scores across grid steps (the sequential
                  grid is the TPU analog of the FPGA's pipelined
                  partition reduction).
  * ``update``  — theta' = theta + lr/B * phi^T err, grid over D blocks;
                  each step owns one theta block, so the write pattern is
                  disjoint and needs no accumulation.

The sigmoid / loss glue between the two runs as plain jnp inside the same
jitted graph and fuses into the surrounding HLO.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .projection import effective_block


def _matvec_kernel(phi_ref, theta_ref, o_ref):
    """Accumulate one D-block's contribution to the scores."""
    partial = jax.lax.dot_general(
        phi_ref[...],
        theta_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (B,)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(pl.program_id(0) != 0)
    def _acc():
        o_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("block_d",))
def matvec(phi, theta, *, block_d: int | None = None):
    """z = phi @ theta with a D-blocked accumulating grid.

    Args:
      phi:   (B, D) float32 encoded batch.
      theta: (D,) float32 parameters.

    Returns:
      (B,) float32 scores.
    """
    b, dim = phi.shape
    assert theta.shape == (dim,)
    bd = block_d or effective_block(dim)
    assert dim % bd == 0
    return pl.pallas_call(
        _matvec_kernel,
        grid=(dim // bd,),
        in_specs=[
            pl.BlockSpec((b, bd), lambda i: (0, i)),
            pl.BlockSpec((bd,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((b,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(phi, theta)


def _update_kernel(theta_ref, phi_ref, err_ref, lr_ref, o_ref):
    """theta block += lr/B * phi_block^T err  (disjoint writes per step)."""
    phi = phi_ref[...]  # (B, BLOCK_D)
    err = err_ref[...]  # (B,)
    grad = jax.lax.dot_general(
        err,
        phi,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (BLOCK_D,)
    b = phi.shape[0]
    o_ref[...] = theta_ref[...] + lr_ref[0] * grad / b


@functools.partial(jax.jit, static_argnames=("block_d",))
def update(theta, phi, err, lr, *, block_d: int | None = None):
    """theta' = theta + lr/B * phi^T err.

    Args:
      theta: (D,) float32.
      phi:   (B, D) float32 encoded batch.
      err:   (B,) float32 residuals (y - sigma(z)).
      lr:    (1,) float32 learning rate.

    Returns:
      (D,) float32 updated parameters.
    """
    b, dim = phi.shape
    assert theta.shape == (dim,) and err.shape == (b,)
    bd = block_d or effective_block(dim)
    assert dim % bd == 0
    return pl.pallas_call(
        _update_kernel,
        grid=(dim // bd,),
        in_specs=[
            pl.BlockSpec((bd,), lambda i: (i,)),
            pl.BlockSpec((b, bd), lambda i: (0, i)),
            pl.BlockSpec((b,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bd,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dim,), jnp.float32),
        interpret=True,
    )(theta, phi, err, lr)


def train_step(theta, phi, y, lr, *, block_d: int | None = None):
    """Fused SGD step: returns (theta', mean NLL loss).

    Composes the two kernels with jnp glue; lowered as one HLO module by
    model.py so rust sees a single executable.
    """
    z = matvec(phi, theta, block_d=block_d)
    p = 1.0 / (1.0 + jnp.exp(-z))
    err = y.astype(jnp.float32) - p
    loss = jnp.mean(jnp.logaddexp(0.0, z) - y * z)
    theta_new = update(theta, phi, err, lr, block_d=block_d)
    return theta_new, loss
