"""Pallas kernel: sparse Johnson-Lindenstrauss transform (paper Eq. 5).

The SJLT maps x in R^n to k concatenated chunks of size d/k; chunk c is

    phi(x)^(c)_i = sum_j 1(eta_c(j) = i) * sigma_c(j) * x_j .

A GPU implementation would scatter-accumulate with atomics; scattered
single-element writes are hostile to TPU vector units, so the kernel
instead *materializes the chunk's selection matrix on the fly* inside
VMEM with a broadcasted-iota comparison (no HBM footprint for the one-hot)
and contracts it on the MXU. The Pallas grid runs one chunk per step —
the hash pair (eta_c, sigma_c) is the only state streamed from HBM,
which is exactly the paper's "no materialized codebook" property: the
(n x d/k) projection never exists outside the current VMEM tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sjlt_kernel(x_ref, eta_ref, sigma_ref, o_ref):
    """One grid step = one SJLT chunk."""
    x = x_ref[...].astype(jnp.float32)  # (B, n)
    eta = eta_ref[0, :]  # (n,) int32 bucket ids in [0, dk)
    sigma = sigma_ref[0, :].astype(jnp.float32)  # (n,) +-1
    n = x.shape[1]
    dk = o_ref.shape[1]
    # One-hot selection built in-register: (n, dk).
    cols = jax.lax.broadcasted_iota(jnp.int32, (n, dk), 1)
    onehot = (eta[:, None] == cols).astype(jnp.float32)
    proj = sigma[:, None] * onehot  # (n, dk) sparse-in-content, dense-in-layout
    o_ref[...] = jax.lax.dot_general(
        x,
        proj,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("d",))
def sjlt(x, eta, sigma, *, d: int):
    """SJLT-encode a batch.

    Args:
      x:     (B, n) float batch.
      eta:   (k, n) int32 bucket indices in [0, d/k).
      sigma: (k, n) float32 in {+1, -1}.
      d:     output dimension, divisible by k.

    Returns:
      (B, d) float32: chunk c occupies columns [c*d/k, (c+1)*d/k).
    """
    b, n = x.shape
    k, n2 = eta.shape
    assert n == n2 and sigma.shape == (k, n)
    assert d % k == 0, f"d={d} must be divisible by k={k}"
    dk = d // k
    return pl.pallas_call(
        _sjlt_kernel,
        grid=(k,),
        in_specs=[
            pl.BlockSpec((b, n), lambda c: (0, 0)),
            pl.BlockSpec((1, n), lambda c: (c, 0)),
            pl.BlockSpec((1, n), lambda c: (c, 0)),
        ],
        out_specs=pl.BlockSpec((b, dk), lambda c: (0, c)),
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=True,
    )(x, eta, sigma)
