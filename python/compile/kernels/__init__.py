"""Layer-1 Pallas kernels for the streaming-HDC stack.

All kernels run with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); each has a pure-jnp oracle in :mod:`ref` that pytest
checks against. Layer 2 (:mod:`compile.model`) composes these into the
jitted functions that ``compile.aot`` lowers to HLO text for the rust
runtime.
"""

from . import logistic, projection, ref, sjlt  # noqa: F401
