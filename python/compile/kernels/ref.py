"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has an exact (up to float error) reference
implementation here. pytest asserts kernel == ref across shape/dtype/seed
sweeps — this file is the correctness ground truth for Layer 1.

The math mirrors the paper:
  * ``project``          — Eq. 4, dense signed random projection
                           ``phi(x) = q(x @ Phi^T)`` with q in
                           {identity, sign, |.|>=t threshold}.
  * ``sjlt``             — Eq. 5, sparse Johnson-Lindenstrauss transform,
                           chunk c of the output is
                           ``sum_j 1(eta_c(j)=i) sigma_c(j) x_j``.
  * ``logistic_forward`` / ``logistic_update`` — Section 7.1's
                           logistic-regression SGD step
                           ``theta <- theta + lr/B * phi^T (y - sigma(z))``.
"""

from __future__ import annotations

import jax.numpy as jnp


def project(x, phi, mode: str = "none", threshold: float = 0.0):
    """Random-projection encode a batch.

    Args:
      x:    (B, n) float batch.
      phi:  (d, n) projection matrix (rows = receptive fields).
      mode: "none" (raw z), "sign" (Eq. 4), or "threshold" (Section 5.3:
            1 where |z| >= threshold else 0).
      threshold: scalar t for mode="threshold".

    Returns:
      (B, d) float32 encoding.
    """
    z = x.astype(jnp.float32) @ phi.T.astype(jnp.float32)
    if mode == "none":
        return z
    if mode == "sign":
        # sign(0) := +1, matching the paper's "+1 if u >= 0".
        return jnp.where(z >= 0, 1.0, -1.0).astype(jnp.float32)
    if mode == "threshold":
        return (jnp.abs(z) >= threshold).astype(jnp.float32)
    raise ValueError(f"unknown mode {mode!r}")


def sjlt(x, eta, sigma, d: int):
    """Sparse JL transform (Eq. 5), one chunk per hash pair.

    Args:
      x:     (B, n) float batch.
      eta:   (k, n) int32, bucket index in [0, d/k) per (chunk, input coord).
      sigma: (k, n) float32 in {+1, -1}.
      d:     total output dimension; must be divisible by k.

    Returns:
      (B, d) float32: concatenation of the k chunk embeddings.
    """
    k, n = eta.shape
    dk = d // k
    chunks = []
    for c in range(k):
        onehot = (eta[c][:, None] == jnp.arange(dk)[None, :]).astype(jnp.float32)
        chunks.append(x.astype(jnp.float32) @ (sigma[c][:, None] * onehot))
    return jnp.concatenate(chunks, axis=1)


def logistic_forward(theta, phi):
    """Scores z = phi @ theta. theta: (D,), phi: (B, D) -> (B,)."""
    return phi.astype(jnp.float32) @ theta.astype(jnp.float32)


def sigmoid(z):
    return 1.0 / (1.0 + jnp.exp(-z))


def logistic_loss(theta, phi, y):
    """Mean negative log-likelihood; y in {0, 1}."""
    z = logistic_forward(theta, phi)
    return jnp.mean(jnp.logaddexp(0.0, z) - y * z)


def logistic_update(theta, phi, y, lr):
    """One SGD step on the mean NLL. Returns (theta', mean_loss)."""
    z = logistic_forward(theta, phi)
    p = sigmoid(z)
    err = y.astype(jnp.float32) - p  # (B,)
    b = phi.shape[0]
    grad = phi.astype(jnp.float32).T @ err / b  # (D,)
    loss = jnp.mean(jnp.logaddexp(0.0, z) - y * z)
    return theta + lr * grad, loss
