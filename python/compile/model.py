"""Layer-2 JAX model: the dense-algebra half of the streaming-HDC paper.

This module composes the Layer-1 Pallas kernels into the jitted functions
the rust coordinator executes via PJRT:

  * ``encode_project_{sign,threshold,none}`` — numeric encoding, Eq. 4 /
    Sec. 5.3 (dense signed RP, thresholded sparse RP, raw projection).
  * ``encode_sjlt``    — numeric encoding, Eq. 5.
  * ``train_step``     — one logistic-regression SGD step over an encoded
    batch (Sec. 7.1). theta is donated so PJRT updates in place.
  * ``fused_train_sign_concat`` — the production hot path: numeric sign-RP
    encode + concat with the (rust-produced) categorical embedding +
    SGD step, one HLO module, one host round trip per batch.
  * ``predict``        — scores for validation / AUC.
  * ``loss_eval``      — mean NLL without update (early stopping, Fig 7B).
  * ``mlp_train_step`` / ``mlp_predict`` — the paper's MLP numeric-encoder
    baseline (Sec. 7.2.3: 512x256x64x16 hidden units), trained jointly
    with the logistic head by jax.grad.

Python never runs at serving/training time: ``compile.aot`` lowers these
once to HLO text that rust loads.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import logistic as lkern
from .kernels import projection as pkern
from .kernels import sjlt as skern

# The paper's MLP baseline: 4 hidden layers, 512x256x64x16 units.
MLP_WIDTHS = (512, 256, 64, 16)


# --------------------------------------------------------------------------
# Numeric encoders
# --------------------------------------------------------------------------


def encode_project_sign(x, phi, threshold):
    """Eq. 4: phi(x) = sign(Phi x). threshold is a live-but-unused input so
    the three projection artifacts share a signature."""
    return (pkern.project(x, phi, threshold, mode="sign"),)


def encode_project_threshold(x, phi, threshold):
    """Sec. 5.3: binary sparse codes, 1 where |Phi x| >= t."""
    return (pkern.project(x, phi, threshold, mode="threshold"),)


def encode_project_none(x, phi, threshold):
    """Raw z = Phi x (composition building block)."""
    return (pkern.project(x, phi, threshold, mode="none"),)


def make_encode_sjlt(d: int):
    """Eq. 5 encoder with output dim baked (shapes must be static for AOT)."""

    def encode_sjlt(x, eta, sigma):
        return (skern.sjlt(x, eta, sigma, d=d),)

    return encode_sjlt


# --------------------------------------------------------------------------
# Logistic regression (Sec. 7.1)
# --------------------------------------------------------------------------


def train_step(theta, phi, y, lr):
    """One minibatch SGD step. Returns (theta', mean NLL)."""
    theta_new, loss = lkern.train_step(theta, phi, y, lr)
    return theta_new, loss


def predict(theta, phi):
    """P(y=1) for an encoded batch."""
    z = lkern.matvec(phi, theta)
    return (1.0 / (1.0 + jnp.exp(-z)),)


def loss_eval(theta, phi, y):
    """Mean NLL without an update (validation / early stopping)."""
    z = lkern.matvec(phi, theta)
    return (jnp.mean(jnp.logaddexp(0.0, z) - y * z),)


def fused_train_sign_concat(theta, x, phi_mat, phic, y, lr):
    """Production hot path: encode numeric + bundle-by-concat + SGD step.

    Args:
      theta:   (d_num + d_cat,) parameters (donated).
      x:       (B, n) numeric batch.
      phi_mat: (d_num, n) projection matrix.
      phic:    (B, d_cat) categorical embedding (rust scatters the Bloom
               indices into this dense buffer).
      y:       (B,) labels in {0, 1}.
      lr:      (1,) learning rate.

    Returns:
      (theta', mean NLL).
    """
    zero = jnp.zeros((1,), jnp.float32)
    phin = pkern.project(x, phi_mat, zero, mode="sign")  # (B, d_num)
    phi = jnp.concatenate([phin, phic.astype(jnp.float32)], axis=1)
    return lkern.train_step(theta, phi, y, lr)


def fused_predict_sign_concat(theta, x, phi_mat, phic):
    """Scores for the fused path (validation / test)."""
    zero = jnp.zeros((1,), jnp.float32)
    phin = pkern.project(x, phi_mat, zero, mode="sign")
    phi = jnp.concatenate([phin, phic.astype(jnp.float32)], axis=1)
    z = lkern.matvec(phi, theta)
    return (1.0 / (1.0 + jnp.exp(-z)),)


# --------------------------------------------------------------------------
# MLP numeric-encoder baseline (Sec. 7.2.3)
# --------------------------------------------------------------------------


def mlp_init(n: int, d_cat: int, seed: int = 0):
    """He-initialized MLP params + logistic head, as a flat tuple.

    Layout: (W1, b1, W2, b2, W3, b3, W4, b4, theta) with
    W_i: (fan_in, width_i), theta: (MLP_WIDTHS[-1] + d_cat,).
    """
    key = jax.random.PRNGKey(seed)
    params = []
    fan_in = n
    for w in MLP_WIDTHS:
        key, k1 = jax.random.split(key)
        scale = jnp.sqrt(2.0 / fan_in)
        params.append(jax.random.normal(k1, (fan_in, w), jnp.float32) * scale)
        params.append(jnp.zeros((w,), jnp.float32))
        fan_in = w
    params.append(jnp.zeros((MLP_WIDTHS[-1] + d_cat,), jnp.float32))
    return tuple(params)


def _mlp_forward(params, x, phic):
    """ReLU MLP over numeric features, concat with categorical embedding."""
    h = x.astype(jnp.float32)
    for i in range(len(MLP_WIDTHS)):
        w, b = params[2 * i], params[2 * i + 1]
        h = jnp.maximum(h @ w + b, 0.0)
    theta = params[-1]
    phi = jnp.concatenate([h, phic.astype(jnp.float32)], axis=1)
    return phi @ theta


def _mlp_loss(params, x, phic, y):
    z = _mlp_forward(params, x, phic)
    return jnp.mean(jnp.logaddexp(0.0, z) - y * z)


def mlp_train_step(*args):
    """One joint SGD step on (MLP weights, logistic head).

    Signature (flattened for AOT): W1,b1,...,W4,b4,theta, x, phic, y, lr
    -> (W1',b1',...,theta', loss).
    """
    nparams = 2 * len(MLP_WIDTHS) + 1
    params = tuple(args[:nparams])
    x, phic, y, lr = args[nparams:]
    loss, grads = jax.value_and_grad(_mlp_loss)(params, x, phic, y)
    new = tuple(p - lr[0] * g for p, g in zip(params, grads))
    return (*new, loss)


def mlp_predict(*args):
    """P(y=1) under the MLP-encoder model: W1,b1,...,theta, x, phic."""
    nparams = 2 * len(MLP_WIDTHS) + 1
    params = tuple(args[:nparams])
    x, phic = args[nparams:]
    z = _mlp_forward(params, x, phic)
    return (1.0 / (1.0 + jnp.exp(-z)),)
