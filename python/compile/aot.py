"""AOT lowering: JAX (L2 + L1) -> HLO text artifacts for the rust runtime.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly. Lowered with
``return_tuple=True`` and unwrapped on the rust side.

Each artifact is one jitted function at one concrete shape profile
(PJRT executables are shape-monomorphic). ``manifest.json`` maps
artifact name -> file, input/output shapes+dtypes, and the semantic
parameters (b, n, d_num, d_cat, k, ...) the rust runtime keys on.

Usage: ``cd python && python -m compile.aot --out ../artifacts``
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


@dataclasses.dataclass(frozen=True)
class Profile:
    """One concrete shape configuration to lower every function at."""

    name: str
    b: int  # batch size
    n: int = 13  # numeric features (Criteo: 13)
    d_num: int = 2048  # numeric encoding dimension
    d_cat: int = 8192  # categorical encoding dimension
    sjlt_k: int = 4  # SJLT chunk count

    @property
    def d_total(self) -> int:  # concat-bundled model dimension
        return self.d_num + self.d_cat


# "small" keeps artifact compile time negligible for tests; "default" is
# the scale the examples/benches run at (d_total ~= the paper's 10k).
PROFILES = {
    "small": Profile("small", b=32, n=13, d_num=256, d_cat=512, sjlt_k=4),
    "default": Profile("default", b=256, n=13, d_num=2048, d_cat=8192, sjlt_k=4),
}

F32 = jnp.float32
I32 = jnp.int32


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_specs(p: Profile):
    """(artifact_name, fn, example_args, semantic_params) for one profile."""
    b, n, dn, dc, dt = p.b, p.n, p.d_num, p.d_cat, p.d_total
    k = p.sjlt_k
    mlp_params = model.mlp_init(n, dc)
    mlp_specs = [_spec(q.shape) for q in mlp_params]
    sem = dict(b=b, n=n, d_num=dn, d_cat=dc, d_total=dt, sjlt_k=k)
    return [
        (
            "encode_project_sign",
            model.encode_project_sign,
            [_spec((b, n)), _spec((dn, n)), _spec((1,))],
            sem,
        ),
        (
            "encode_project_threshold",
            model.encode_project_threshold,
            [_spec((b, n)), _spec((dn, n)), _spec((1,))],
            sem,
        ),
        (
            "encode_project_none",
            model.encode_project_none,
            [_spec((b, n)), _spec((dn, n)), _spec((1,))],
            sem,
        ),
        (
            "encode_sjlt",
            model.make_encode_sjlt(dn),
            [_spec((b, n)), _spec((k, n), I32), _spec((k, n))],
            sem,
        ),
        (
            "train_step",
            model.train_step,
            [_spec((dt,)), _spec((b, dt)), _spec((b,)), _spec((1,))],
            sem,
        ),
        (
            "predict",
            model.predict,
            [_spec((dt,)), _spec((b, dt))],
            sem,
        ),
        (
            "loss_eval",
            model.loss_eval,
            [_spec((dt,)), _spec((b, dt)), _spec((b,))],
            sem,
        ),
        (
            "fused_train_sign_concat",
            model.fused_train_sign_concat,
            [
                _spec((dt,)),
                _spec((b, n)),
                _spec((dn, n)),
                _spec((b, dc)),
                _spec((b,)),
                _spec((1,)),
            ],
            sem,
        ),
        (
            "fused_predict_sign_concat",
            model.fused_predict_sign_concat,
            [_spec((dt,)), _spec((b, n)), _spec((dn, n)), _spec((b, dc))],
            sem,
        ),
        (
            "mlp_train_step",
            model.mlp_train_step,
            mlp_specs + [_spec((b, n)), _spec((b, dc)), _spec((b,)), _spec((1,))],
            sem,
        ),
        (
            "mlp_predict",
            model.mlp_predict,
            mlp_specs + [_spec((b, n)), _spec((b, dc))],
            sem,
        ),
    ]


def lower_all(out_dir: str, profile_names: list[str]) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest: dict = {"artifacts": {}, "mlp_widths": list(model.MLP_WIDTHS)}
    for pname in profile_names:
        prof = PROFILES[pname]
        for fname, fn, args, sem in build_specs(prof):
            art_name = f"{fname}__{pname}"
            lowered = jax.jit(fn).lower(*args)
            text = to_hlo_text(lowered)
            fpath = f"{art_name}.hlo.txt"
            with open(os.path.join(out_dir, fpath), "w") as f:
                f.write(text)
            out_aval = lowered.out_info
            outs = [
                {"shape": list(o.shape), "dtype": np.dtype(o.dtype).name}
                for o in jax.tree_util.tree_leaves(out_aval)
            ]
            manifest["artifacts"][art_name] = {
                "file": fpath,
                "fn": fname,
                "profile": pname,
                "inputs": [
                    {"shape": list(a.shape), "dtype": np.dtype(a.dtype).name}
                    for a in args
                ],
                "outputs": outs,
                "params": sem,
            }
            print(f"  {art_name}: {len(text)} chars, {len(args)} inputs")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--profiles",
        nargs="+",
        default=["small", "default"],
        choices=sorted(PROFILES),
    )
    args = ap.parse_args()
    manifest = lower_all(args.out, args.profiles)
    print(f"wrote {len(manifest['artifacts'])} artifacts to {args.out}")


if __name__ == "__main__":
    main()
