#!/usr/bin/env bash
# Regenerate BENCH_encode.json from the repo root.
#
# The measured work is fully seeded (see rust/src/perf.rs), so reruns
# measure the identical workload; only wall-clock numbers vary with the
# host. Commit the refreshed file with perf-affecting PRs so the perf
# trajectory stays reviewable.
#
# Knobs (env): BENCH_MS (per-measurement budget ms, default 300),
# SHDC_BENCH_RECORDS (pipeline-scaling records, default 60000),
# BENCH_OUT (output path, default BENCH_encode.json).
set -euo pipefail
cd "$(dirname "$0")/.."

export BENCH_OUT="${BENCH_OUT:-BENCH_encode.json}"
cargo run --release --bin bench_snapshot
echo "snapshot written to ${BENCH_OUT}"
