#!/usr/bin/env bash
# Local CI: formatting gate + tier-1 build/test. Run from anywhere.
#
#   scripts/ci.sh                  # fmt check + build + test
#   scripts/ci.sh --bench          # additionally refresh BENCH_encode.json
#                                  # and run the bench-trend gate against
#                                  # the previously committed snapshot
#                                  # (fails on >15% encode-median
#                                  # regressions; skips cleanly while the
#                                  # committed snapshot is the nulls-only
#                                  # placeholder)
#   scripts/ci.sh --simd           # additionally run the test suite with
#                                  # the std::simd kernel backend (needs a
#                                  # nightly toolchain via rustup)
#   scripts/ci.sh --simd --bench   # flags combine in any order
set -euo pipefail
cd "$(dirname "$0")/.."

run_simd=0
run_bench=0
for arg in "$@"; do
    case "$arg" in
        --simd) run_simd=1 ;;
        --bench) run_bench=1 ;;
        *)
            echo "unknown flag: $arg (expected --simd and/or --bench)" >&2
            exit 2
            ;;
    esac
done

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy -- -D warnings =="
cargo clippy --all-targets -- -D warnings

echo "== cargo build --release =="
cargo build --release

echo "== cargo build --release --bin serve_bench =="
cargo build --release --bin serve_bench

echo "== cargo test -q =="
cargo test -q

# The serving subsystem's end-to-end smoke (submit → micro-batch →
# encode → AM score → respond vs offline references). Also part of the
# full suite above; the dedicated invocation keeps the serve contract
# visible in CI logs and runnable in isolation.
echo "== cargo test -q --test serve_smoke =="
cargo test -q --test serve_smoke

# Multi-tenant serve contract in isolation: two registry models with
# different dims/seeds/precisions through the one shared pool
# (bit-identical to per-model offline references), model-homogeneous
# batch cuts, and per-tenant quota shedding that leaves the quiet
# tenant's error rate and tail untouched.
echo "== cargo test -q --test serve_smoke multi_model_ =="
cargo test -q --test serve_smoke multi_model_

# Sharded-AM differential suite in isolation: sharded top-k/top-1
# exactly equal to the single-thread scan across precision × shard
# count × class count (ragged shards, k > shard, constructed ties), and
# scorer-count invariance. Also in the full suite; the dedicated leg
# keeps the exact-equality contract visible in CI logs.
echo "== cargo test -q --test am_sharding =="
cargo test -q --test am_sharding

# The fault-injection matrix (worker panics, stalls, stalled batcher,
# lossy recycle): every request must reach a terminal outcome, surviving
# output must be bit-identical to a no-fault run, and the failure
# counters must match the injected plan. Also in the full suite; the
# dedicated leg keeps the robustness contract visible in CI logs.
echo "== cargo test -q --test fault_injection =="
cargo test -q --test fault_injection

# Stage-span tracing contract in isolation: monotone telescoping spans,
# deterministic 1-in-N sampling, ring wraparound accounting, per-model
# histogram reconciliation, and failed-trace handling under injected
# panics. Also in the full suite; the dedicated leg keeps the
# observability contract visible in CI logs.
echo "== cargo test -q --test obs_tracing =="
cargo test -q --test obs_tracing

# Monitoring contract in isolation: zero-traffic windows stay finite and
# healthy, /metrics parses line-for-line and two scrapes reconcile
# exactly with the traffic between them, publisher shutdown is
# idempotent, and an injected worker stall flips /health to breach and
# back. Also in the full suite; the dedicated leg keeps the exposition
# contract visible in CI logs.
echo "== cargo test -q --test obs_export =="
cargo test -q --test obs_export

# Overload smoke: a tiny closed-loop sweep plus the open-loop phase at
# 2.5x capacity must TERMINATE with a nonzero shed rate rather than
# hang — the cheapest end-to-end check that admission control actually
# sheds under saturation. SHDC_SERVE_CLASSES keeps the final many-class
# leg (Zipf workload through the sharded scan, per-shard counters
# asserted in-binary) small enough for CI while still multi-shard.
# --trace-out adds the traced closed+open runs: the binary writes the
# sampled spans as JSONL, re-reads the file, and asserts every line
# parses and every trace's stage spans telescope within its end-to-end
# latency. --metrics-addr adds the live-exporter leg: the binary scrapes
# its own /metrics endpoint mid-run and at end-of-run, parses every
# exposition line in-binary, and asserts the scraped counters reconcile
# with the client-side completion counts.
echo "== serve_bench overload + many-class + trace-dump + metrics smoke =="
SHDC_SERVE_REQUESTS=2000 SHDC_SERVE_CLIENTS=4 SHDC_SERVE_OPEN_REQUESTS=2000 \
    SHDC_SERVE_CLASSES=200 \
    cargo run --release --bin serve_bench -- --trace-out target/serve_traces.jsonl \
    --metrics-addr 127.0.0.1:0

if [[ "$run_simd" == 1 ]]; then
    # The kernel differential suite (tests/kernel_equivalence.rs) must
    # pass with the simd feature both on and off, and the encoder
    # equivalence suites must behave identically in both builds.
    echo "== cargo +nightly test -q --features simd =="
    cargo +nightly test -q --features simd
fi

if [[ "$run_bench" == 1 ]]; then
    echo "== bench snapshot + trend gate =="
    # The snapshot path honors BENCH_OUT (bench_snapshot.sh default:
    # BENCH_encode.json); gate against the same file we regenerate.
    out="${BENCH_OUT:-BENCH_encode.json}"
    # Baseline = the COMMITTED snapshot (not the working-tree file, which
    # may hold a previous uncommitted regeneration — gating against it
    # would let a regressed run become its own baseline on the next run).
    # Falls back to the on-disk file when the path is untracked (e.g. a
    # BENCH_OUT override outside the repo).
    baseline="$(mktemp)"
    trap 'rm -f "$baseline"' EXIT
    if ! git show "HEAD:$out" > "$baseline" 2>/dev/null; then
        if [[ -f "$out" ]]; then
            cp "$out" "$baseline"
        else
            : > "$baseline"
        fi
    fi
    scripts/bench_snapshot.sh
    # Fails (non-zero) when any encode median regressed >15% vs the
    # committed snapshot; skips cleanly when the baseline held no
    # measured results. Tolerance override: SHDC_TREND_TOL=0.25.
    cargo run --release --bin bench_trend -- "$baseline" "$out"
fi
