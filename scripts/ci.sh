#!/usr/bin/env bash
# Local CI: formatting gate + tier-1 build/test. Run from anywhere.
#
#   scripts/ci.sh          # fmt check + build + test
#   scripts/ci.sh --bench  # additionally refresh BENCH_encode.json
set -euo pipefail
cd "$(dirname "$0")/.."

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

if [[ "${1:-}" == "--bench" ]]; then
    echo "== bench snapshot =="
    scripts/bench_snapshot.sh
fi
